"""Fleet subsystem tests (ISSUE 2).

Covered:
  * incremental-vs-batch equivalence: the multiplexer's per-step
    evaluation must produce byte-identical anomalies to a terminal
    ``evaluate_all`` on the concatenated batch, per job — including the
    hang path and with other (overlapping-name) jobs in the same fleet;
  * chunked-vs-line-by-line JSONL decoder equality on the same file;
  * tolerant decode of truncated/corrupt trailing lines (+ skip count);
  * shared-interning correctness across jobs with overlapping op names;
  * watermark semantics and late-event accounting;
  * directory replay through the multiplexer;
  * daemon ``attach_fleet`` seam and idempotent ``stop()``.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.columnar import EventBatch
from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind, TraceEvent
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import (FleetConfig, FleetMultiplexer, FleetReplayer,
                         SharedInterner)

N = 32


@pytest.fixture(scope="module")
def world():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng0 = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    for seed in range(3):
        eng0.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(4))
    eng0.learn_healthy()
    return prog, store


def _sig(a):
    """Byte-level anomaly signature: rendered line + canonical evidence."""
    return (str(a), json.dumps(a.evidence, sort_keys=True, default=str))


def _step_chunks(batch):
    order, uniq, bounds = batch.step_index()
    return [batch.take(order[bounds[i]:bounds[i + 1]])
            for i in range(uniq.size)]


SCENARIOS = {
    "healthy": [],
    "gc": [Injection(kind="gc", duration=0.02, period_ops=5)],
    "underclock": [Injection(kind="underclock", ranks=(5,), factor=2.5,
                             start_step=3)],
    "jitter": [Injection(kind="network_jitter", factor=3.0, start_step=3)],
    "hang": [Injection(kind="hang", ranks=(7,), at_step=2)],
}


def test_incremental_matches_batch_per_job(world):
    """Every job's streamed anomalies == terminal evaluate_all, even with
    the jobs multiplexed into ONE fleet with shared interning."""
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    oracle, batches = {}, {}
    for name, inj in SCENARIOS.items():
        batch = ClusterSimulator(N, prog, seed=7,
                                 injections=inj).run_batch(6)
        batches[name] = batch
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(batch)
        oracle[name] = [_sig(a) for a in eng.evaluate_all()]
        mux.add_job(name, EngineConfig(backend="dense-train", num_ranks=N))
    # interleave the jobs' per-step chunks round-robin (concurrent streams)
    pending = {name: _step_chunks(b) for name, b in batches.items()}
    while any(pending.values()):
        for name, chunks in pending.items():
            if chunks:
                mux.ingest(name, chunks.pop(0))
    got = {name: [] for name in SCENARIOS}
    for fa in mux.poll() + mux.finalize():
        got[fa.job_id].append(_sig(fa.anomaly))
    for name in SCENARIOS:
        assert got[name] == oracle[name], name
    assert oracle["healthy"] == []          # no cross-job contamination
    assert any(oracle[k] for k in ("gc", "underclock", "jitter", "hang"))


def test_engine_evaluate_new_steps_matches_evaluate_all(world):
    prog, store = world
    batch = ClusterSimulator(N, prog, seed=7,
                             injections=SCENARIOS["gc"]).run_batch(5)
    bulk = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    bulk.ingest_batch(batch)
    expect = [_sig(a) for a in bulk.evaluate_all()]
    inc = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    got = []
    for i, chunk in enumerate(_step_chunks(batch)):
        inc.ingest_batch(chunk)
        got.extend(inc.evaluate_new_steps(upto=i))  # watermark: step < max
    got.extend(inc.evaluate_new_steps())            # flush
    got.extend(inc.check_hangs())
    assert [_sig(a) for a in got] == expect


def test_chunked_jsonl_decoder_equals_line_by_line(world, tmp_path):
    prog, _ = world
    batch = ClusterSimulator(N, prog, seed=3).run_batch(3)
    path = str(tmp_path / "job.jsonl")
    batch.write_jsonl(path)
    a = EventBatch.from_jsonl(path)
    b = EventBatch.from_jsonl_chunked(path, chunk_bytes=4096, max_workers=3)
    assert len(a) == len(b) == len(batch)
    assert a.names == b.names and a.groups == b.groups
    for col in ("kind", "name_id", "rank", "issue_ts", "start_ts", "end_ts",
                "step", "nbytes", "tokens", "group_id"):
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert np.array_equal(a.flops, b.flops, equal_nan=True)
    assert a.extra == b.extra


def test_from_jsonl_skips_corrupt_trailing_lines(tmp_path):
    path = str(tmp_path / "killed.jsonl")
    evs = [TraceEvent(EventKind.STEP, f"step_{i}", 0, i, i, i + 1, step=i,
                      meta={"tokens": 8}) for i in range(5)]
    EventBatch.from_events(evs).write_jsonl(path)
    with open(path, "a") as f:
        f.write('not json at all\n')
        f.write('{"k":"step","n":"torn')       # truncated mid-write
    with pytest.warns(UserWarning, match="skipped 2"):
        batch, skipped = EventBatch.from_jsonl(path, with_skip_count=True)
    assert skipped == 2 and len(batch) == 5
    with pytest.warns(UserWarning, match="skipped 2"):
        batch2, skipped2 = EventBatch.from_jsonl_chunked(
            path, chunk_bytes=64, with_skip_count=True)
    assert skipped2 == 2 and len(batch2) == 5
    assert batch2.to_events() == batch.to_events()


def test_shared_interning_across_jobs():
    """Jobs with overlapping op names share one id space losslessly."""
    interner = SharedInterner()
    ev_a = [TraceEvent(EventKind.KERNEL_COMPUTE, n, r, 0.0, 0.0, 1.0, step=0,
                       meta={"flops": 1.0, "group": "dp"})
            for n in ("matmul", "attn", "norm") for r in range(2)]
    ev_b = [TraceEvent(EventKind.KERNEL_COMPUTE, n, r, 0.0, 0.0, 1.0, step=0,
                       meta={"flops": 1.0, "group": "pp"})
            for n in ("norm", "embed", "matmul") for r in range(2)]
    a = interner.adopt(EventBatch.from_events(ev_a))
    b = interner.adopt(EventBatch.from_events(ev_b))
    assert a.names is interner.names and b.names is interner.names
    assert interner.names == ["matmul", "attn", "norm", "embed"]
    assert interner.groups == ["dp", "pp"]
    # same string -> same id across jobs
    assert a.name_id[0] == b.name_id[4] == interner.names.index("matmul")
    # adoption is lossless row-wise
    assert [e.name for e in a.to_events()] == [e.name for e in ev_a]
    assert [e.name for e in b.to_events()] == [e.name for e in ev_b]
    assert [e.meta.get("group") for e in b.to_events()] == ["pp"] * 6
    # shared-interning concat needs no remap and keeps the shared tables
    m = EventBatch.concat([a, b])
    assert m.names is interner.names
    assert [e.name for e in m.to_events()] == \
        [e.name for e in ev_a] + [e.name for e in ev_b]


def test_watermark_and_late_events(world):
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    mux.add_job("j", EngineConfig(backend="dense-train", num_ranks=N))
    batch = ClusterSimulator(N, prog, seed=5).run_batch(4)
    chunks = _step_chunks(batch)
    mux.ingest("j", chunks[0])
    assert mux.job("j").evaluated == set()        # watermark holds step 0
    mux.ingest("j", chunks[1])
    assert mux.job("j").evaluated == {0}          # step 1 closed step 0
    mux.ingest("j", chunks[0])                    # stale re-delivery
    assert mux.job("j").late_events == len(chunks[0])
    mux.ingest("j", chunks[2])
    mux.ingest("j", chunks[3])
    mux.finalize("j")
    assert mux.job("j").evaluated == {0, 1, 2, 3}
    st = mux.stats()["j"]
    assert st["events"] == len(batch) + len(chunks[0])
    assert st["late_events"] == len(chunks[0])


def test_replay_directory_matches_direct_oracle(world, tmp_path):
    """Replaying recorded JSONL logs through the fleet = diagnosing the
    decoded batches directly (same rounding, same anomalies)."""
    prog, store = world
    logdir = tmp_path / "logs"
    os.makedirs(logdir)
    jobs = {"jobA-gc": SCENARIOS["gc"], "jobB-healthy": []}
    for job_id, inj in jobs.items():
        b = ClusterSimulator(N, prog, seed=7, injections=inj).run_batch(5)
        b.write_jsonl(str(logdir / f"{job_id}.jsonl"))
    oracle = {}
    for job_id in jobs:
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(EventBatch.from_jsonl(str(logdir / f"{job_id}.jsonl")))
        oracle[job_id] = [_sig(a) for a in eng.evaluate_all()]
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    for job_id in jobs:
        mux.add_job(job_id, EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux, chunk_bytes=1 << 16).replay_dir(str(logdir))
    got = {j: [] for j in jobs}
    for fa in mux.poll():
        got[fa.job_id].append(_sig(fa.anomaly))
    assert stats.files == 2 and stats.skipped_lines == 0
    assert stats.events == sum(s["events"] for s in mux.stats().values())
    for job_id in jobs:
        assert got[job_id] == oracle[job_id], job_id
    assert got["jobB-healthy"] == []


def test_concurrent_ingest_threads(world):
    """Jobs fed from separate threads (the daemon deployment shape) still
    match their single-threaded oracles — per-job locks + locked shared
    interner/stream."""
    import threading
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    oracle = {}
    work = {}
    for name in ("gc", "underclock", "jitter"):
        batch = ClusterSimulator(N, prog, seed=7,
                                 injections=SCENARIOS[name]).run_batch(6)
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(batch)
        oracle[name] = [_sig(a) for a in eng.evaluate_all()]
        mux.add_job(name, EngineConfig(backend="dense-train", num_ranks=N))
        work[name] = _step_chunks(batch)
    threads = [threading.Thread(
        target=lambda n=name: [mux.ingest(n, c) for c in work[n]])
        for name in work]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    got = {name: [] for name in work}
    for fa in mux.finalize():
        got[fa.job_id].append(_sig(fa.anomaly))
    for name in work:
        assert got[name] == oracle[name], name


def test_single_rank_suspect_does_not_declare_fleet_hang():
    """The hang threshold uses the job-wide rank count (engine config),
    not the ranks seen so far — one daemon's first drain containing a
    HANG_SUSPECT must not latch a majority hang on a 64-rank job."""
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1))
    mux.add_job("big", EngineConfig(backend="dense-train", num_ranks=64))
    sus = TraceEvent(EventKind.HANG_SUSPECT, "hang_suspect", 7,
                     30.0, 30.0, 30.0, step=0,
                     meta={"stack": ["train_step", "allreduce"]})
    mux.ingest("big", [sus])
    assert not mux.job("big").hang_reported
    assert mux.poll() == []
    # a majority of the configured ranks reporting DOES declare it
    mux.ingest("big", [
        TraceEvent(EventKind.HANG_SUSPECT, "hang_suspect", r,
                   30.0, 30.0, 30.0, step=0,
                   meta={"stack": ["train_step", "allreduce"]})
        for r in range(32)])
    assert mux.job("big").hang_reported
    anoms = mux.poll()
    assert len(anoms) == 1 and anoms[0].anomaly.kind == "hang"


def _write_mixed_fleet_logs(logdir, prog):
    """Four jobs, four storage shapes: plain JSONL, rotated FCS v1
    pieces, FCS v2 with a truncated tail, and single-file FCS v1 —
    the mixed directory the parallel pipeline must replay exactly like
    serial.  Returns job_id -> decoded oracle batch."""
    from repro import store as trace_store
    os.makedirs(logdir, exist_ok=True)
    oracle = {}

    # job-a: JSONL (diagnosis sees the rounded timestamps)
    b = ClusterSimulator(N, prog, seed=11,
                         injections=SCENARIOS["gc"]).run_batch(5)
    jp = os.path.join(logdir, "job-a.jsonl")
    b.write_jsonl(jp)
    oracle["job-a"] = EventBatch.from_jsonl(jp)

    # job-b: FCS v1 rotated into .segNNN pieces, one segment per step
    b = ClusterSimulator(N, prog, seed=12,
                         injections=SCENARIOS["underclock"]).run_batch(5)
    w = trace_store.SegmentedTraceWriter(
        os.path.join(logdir, "job-b.fcs"), codec="fcs", rotate_bytes=1)
    for c in _step_chunks(b):
        w.write(c)
    assert len(w.paths) >= 3
    oracle["job-b"] = EventBatch.concat(
        [trace_store.read_fcs(p) for p in w.paths])

    # job-c: FCS v2 with a corrupt trailing segment (killed writer) —
    # the intact leading segments still replay, the tail is counted
    b = ClusterSimulator(N, prog, seed=13,
                         injections=SCENARIOS["jitter"]).run_batch(5)
    cp = os.path.join(logdir, "job-c.fcs2")
    trace_store.write_fcs(b, cp, version=2)
    intact = os.path.getsize(cp)
    trace_store.write_fcs(b, cp, version=2)
    with open(cp, "r+b") as f:
        f.truncate(intact + 57)
    oracle["job-c"] = b

    # job-d: single-file FCS v1
    b = ClusterSimulator(N, prog, seed=14).run_batch(5)
    trace_store.write_fcs(b, os.path.join(logdir, "job-d.fcs"))
    oracle["job-d"] = b

    # job-e: FCS v3 (per-segment stats block), one segment per step
    b = ClusterSimulator(N, prog, seed=15,
                         injections=SCENARIOS["gc"]).run_batch(5)
    ep = os.path.join(logdir, "job-e.fcs3")
    for c in _step_chunks(b):
        trace_store.write_fcs(c, ep, version=3)
    oracle["job-e"] = b
    return oracle


def _replay(logdir, store, fleet_cfg=None, topo=None, worker_kind=None,
            **replayer_kw):
    mux = FleetMultiplexer(fleet_cfg or FleetConfig(watermark_delay=1),
                           history=store)
    # register in REVERSE order on purpose: equivalence must not lean on
    # registration order matching the replayer's sorted-path order
    for job in ("job-e", "job-d", "job-c", "job-b", "job-a"):
        mux.add_job(job, EngineConfig(backend="dense-train", num_ranks=N))
        if topo:
            mux.set_topology(job, **topo.get(job, {}))
    stats = FleetReplayer(mux, **replayer_kw).replay_dir(
        logdir, worker_kind=worker_kind)
    return stats, [(fa.job_id, fa.origin, _sig(fa.anomaly))
                   for fa in mux.poll()], mux


def test_parallel_replay_matches_serial_on_mixed_dir(world, tmp_path):
    """The tentpole gate: a mixed JSONL / rotated-FCS / truncated-v2
    directory replayed with per-job workers must produce byte-identical
    anomalies AND stats to the serial (job_workers=1) replay."""
    prog, store = world
    logdir = str(tmp_path / "logs")
    oracle = _write_mixed_fleet_logs(logdir, prog)

    s1, a1, _ = _replay(logdir, store, job_workers=1)
    s4, a4, _ = _replay(logdir, store, job_workers=4)
    assert s4.job_workers == 4 and s1.job_workers == 1
    assert a4 == a1
    assert a1                                 # the scenarios actually alarm
    assert s4.events == s1.events
    assert s4.per_job == s1.per_job
    assert list(s4.per_job) == sorted(s4.per_job)     # deterministic order
    assert s4.files == s1.files
    assert s4.corrupt_files == s1.corrupt_files == 1  # job-c's torn tail
    assert s4.skipped_lines == s1.skipped_lines == 0
    # every job's full (intact) log was ingested
    assert s4.per_job["job-a"] == len(oracle["job-a"])
    assert s4.per_job["job-b"] == len(oracle["job-b"])
    assert s4.per_job["job-c"] == len(oracle["job-c"])  # leading segment
    assert s4.per_job["job-d"] == len(oracle["job-d"])
    assert s4.per_job["job-e"] == len(oracle["job-e"])  # FCS v3
    # and prefetch=0 (no pipeline) is equivalent too
    s0, a0, _ = _replay(logdir, store, job_workers=4, prefetch=0)
    assert a0 == a1 and s0.per_job == s1.per_job


def test_parallel_replay_fleet_tier_matches_serial(world, tmp_path):
    """Cross-job correlation is order-sensitive; the deferred fleet tier
    must make parallel replay's INFRASTRUCTURE reclassifications
    byte-identical to serial replay's."""
    prog, store = world
    from repro import store as trace_store
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    # three jitter jobs on one shared rack + one healthy control
    for i, job in enumerate(("job-a", "job-b", "job-c")):
        b = ClusterSimulator(N, prog, seed=20 + i,
                             injections=SCENARIOS["jitter"]).run_batch(6)
        trace_store.write_fcs(b, os.path.join(logdir, f"{job}.fcs"))
    trace_store.write_fcs(
        ClusterSimulator(N, prog, seed=30).run_batch(6),
        os.path.join(logdir, "job-d.fcs"))
    topo = {j: {"rack": "rack0", "switch": "sw0"}
            for j in ("job-a", "job-b", "job-c")}
    topo["job-d"] = {"rack": "rack9", "switch": "sw9"}

    def cfg():
        return FleetConfig(watermark_delay=1,
                           fleet_detectors=["cross_job_failslow"])

    s1, a1, _ = _replay(logdir, store, fleet_cfg=cfg(), topo=topo,
                        job_workers=1)
    s4, a4, _ = _replay(logdir, store, fleet_cfg=cfg(), topo=topo,
                        job_workers=4)
    assert a4 == a1
    fleet_emissions = [x for x in a1 if x[1] == "fleet"]
    assert len(fleet_emissions) >= 2          # the correlator actually fired
    assert s4.per_job == s1.per_job


def test_parallel_replay_identical_timestamps_across_jobs(world, tmp_path):
    """Two jobs carrying the SAME recorded timestamps (one trace under
    two job ids) tie on every anomaly ts; the stream's job-id tie-break
    must keep parallel replay deterministic and equal to serial."""
    prog, store = world
    from repro import store as trace_store
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    b = ClusterSimulator(N, prog, seed=41,
                         injections=SCENARIOS["gc"]).run_batch(5)
    for job in ("job-x", "job-y"):
        trace_store.write_fcs(b, os.path.join(logdir, f"{job}.fcs"))

    def run(jw):
        mux = FleetMultiplexer(FleetConfig(watermark_delay=1),
                               history=store)
        for job in ("job-y", "job-x"):          # reversed registration
            mux.add_job(job, EngineConfig(backend="dense-train",
                                          num_ranks=N))
        FleetReplayer(mux).replay_dir(logdir, job_workers=jw)
        return [(fa.job_id, fa.ts, _sig(fa.anomaly)) for fa in mux.poll()]

    serial = run(1)
    assert serial                       # the scenario alarms, ts all tie
    for _ in range(3):                  # scheduling-independence
        assert run(2) == serial


def test_fcs2_zlib_fallback_clamps_zstd_level(tmp_path):
    """A zstd-tuned level (1..22) must survive the zlib fallback — zlib
    only accepts -1..9 and a raise here would silently kill the daemon
    spill path."""
    from repro import store as trace_store
    prog = get_config("llama-20b-paper")
    b = ClusterSimulator(8, program_from_config(prog, num_chips=8),
                         seed=1).run_batch(2)
    path = str(tmp_path / "lvl.fcs2")
    trace_store.write_fcs(b, path, version=2, compression="zlib", level=19)
    got = trace_store.read_trace(path)
    assert len(got) == len(b)
    assert np.array_equal(got.end_ts, b.end_ts)


def test_replay_stats_merge():
    from repro.fleet import ReplayStats
    a = ReplayStats(files=2, events=10, skipped_lines=1, corrupt_files=0,
                    per_job={"a": 10})
    b = ReplayStats(files=1, events=5, corrupt_files=2, per_job={"b": 5})
    a.merge(b)
    assert (a.files, a.events, a.skipped_lines, a.corrupt_files) == \
        (3, 15, 1, 2)
    assert a.per_job == {"a": 10, "b": 5}


# --------------------------------------------------------------------- #
# process-sharded replay: FCS-over-IPC job workers (ISSUE 8)            #
# --------------------------------------------------------------------- #

def test_process_replay_matches_serial_on_mixed_dir(world, tmp_path):
    """The tentpole gate: the mixed JSONL / rotated-FCS / truncated-v2 /
    FCS-v3 directory replayed with PROCESS workers must produce
    byte-identical anomalies, stats, and per-job end state to serial."""
    prog, store = world
    logdir = str(tmp_path / "logs")
    oracle = _write_mixed_fleet_logs(logdir, prog)

    s1, a1, m1 = _replay(logdir, store, job_workers=1)
    sp, ap, mp_ = _replay(logdir, store, job_workers=2,
                          worker_kind="process")
    assert sp.worker_kind == "process" and sp.job_workers == 2
    assert s1.worker_kind == "serial"
    assert ap == a1
    assert a1                                 # the scenarios actually alarm
    assert sp.events == s1.events
    assert sp.per_job == s1.per_job
    assert list(sp.per_job) == sorted(sp.per_job)     # deterministic order
    assert sp.files == s1.files
    assert sp.corrupt_files == s1.corrupt_files == 1  # job-c's torn tail
    assert sp.skipped_lines == s1.skipped_lines == 0
    assert sp.per_job["job-e"] == len(oracle["job-e"])   # FCS v3 job
    # per-job end state mirrored back from the workers == serial state
    assert mp_.stats() == m1.stats()


def test_process_replay_fleet_tier_matches_serial(world, tmp_path):
    """The deferred-and-replayed fleet tier: cross-job INFRASTRUCTURE
    reclassifications from process workers must be byte-identical to
    serial, with jobs registered in reverse order."""
    prog, store = world
    from repro import store as trace_store
    logdir = str(tmp_path / "logs")
    os.makedirs(logdir)
    for i, job in enumerate(("job-a", "job-b", "job-c")):
        b = ClusterSimulator(N, prog, seed=20 + i,
                             injections=SCENARIOS["jitter"]).run_batch(6)
        trace_store.write_fcs(b, os.path.join(logdir, f"{job}.fcs"))
    trace_store.write_fcs(
        ClusterSimulator(N, prog, seed=30).run_batch(6),
        os.path.join(logdir, "job-d.fcs"))
    topo = {j: {"rack": "rack0", "switch": "sw0"}
            for j in ("job-a", "job-b", "job-c")}
    topo["job-d"] = {"rack": "rack9", "switch": "sw9"}

    def cfg():
        return FleetConfig(watermark_delay=1,
                           fleet_detectors=["cross_job_failslow"])

    s1, a1, _ = _replay(logdir, store, fleet_cfg=cfg(), topo=topo,
                        job_workers=1)
    sp, ap, _ = _replay(logdir, store, fleet_cfg=cfg(), topo=topo,
                        job_workers=3, worker_kind="process")
    assert ap == a1
    assert len([x for x in a1 if x[1] == "fleet"]) >= 2
    assert sp.per_job == s1.per_job


def test_process_replay_telemetry_merge(world, tmp_path):
    """Worker telemetry registries absorb into the parent's: the merged
    snapshot must equal the serial run's (minus the timestamp)."""
    prog, store = world
    logdir = str(tmp_path / "logs")
    _write_mixed_fleet_logs(logdir, prog)
    _, _, m1 = _replay(logdir, store, job_workers=1)
    _, _, mp_ = _replay(logdir, store, job_workers=2,
                        worker_kind="process")
    snap1, snapp = m1.telemetry.snapshot(), mp_.telemetry.snapshot()
    assert snapp["counters"] == snap1["counters"]
    # gauges equal except wall-clock rates (nondeterministic by nature)
    wall = ("replay.events_per_s",)
    g1 = {k: v for k, v in snap1["gauges"].items() if k not in wall}
    gp = {k: v for k, v in snapp["gauges"].items() if k not in wall}
    assert gp == g1
    assert "replay.events_per_s" in snapp["gauges"]   # still reported


def test_process_pool_batches_ingest_roundtrip(world):
    """The live-streaming IPC shape: EventBatch chunks shipped as FCS
    bytes (``TASK_BATCHES``) through a worker process diagnose exactly
    like local ``ingest`` of the same chunks."""
    prog, store = world
    from repro.fleet.ipc import TASK_BATCHES, ProcessWorkerPool
    from repro.store import encode_batch_bytes
    batch = ClusterSimulator(N, prog, seed=61,
                             injections=SCENARIOS["gc"]).run_batch(5)
    chunks = _step_chunks(batch)
    cfg = EngineConfig(backend="dense-train", num_ranks=N)

    mux1 = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    mux1.add_job("job-x", cfg)
    for c in chunks:
        mux1.ingest("job-x", c)
    mux1.flush("job-x")
    oracle = [(fa.job_id, _sig(fa.anomaly)) for fa in mux1.poll()]

    mux2 = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    mux2.add_job("job-x", cfg)
    init = {"history": store,
            "fleet": {"watermark_delay": 1, "backend": mux2.cfg.backend,
                      "max_pending_rows": None},
            "replay": {}}

    def on_anoms(job_id, items):
        job = mux2.job(job_id)
        for ts, a in items:
            mux2.stream.push(job_id, a, ts)
            job.count_anomaly()

    pool = ProcessWorkerPool(1, init)
    try:
        pool.submit((TASK_BATCHES, "job-x",
                     [encode_batch_bytes(c) for c in chunks], cfg, False))
        results = pool.drain(on_anomalies=on_anoms)
    finally:
        pool.close()
    res = results["job-x"]
    mux2.interner.merge_tables(res["names"], res["groups"])
    mux2.telemetry.absorb(res["telemetry"])
    mux2.restore_job_state("job-x", res["state"])
    got = [(fa.job_id, _sig(fa.anomaly)) for fa in mux2.poll()]
    assert got == oracle and oracle
    assert res["stats"].events == len(batch)
    assert res["stats"].per_job == {"job-x": len(batch)}
    assert res["stats"].worker_kind == "process"
    assert mux2.stats() == mux1.stats()


def test_process_pool_worker_error_propagates(world):
    """A job that blows up inside a worker surfaces as a RuntimeError
    carrying the worker traceback — not a hang, not silence."""
    _, store = world
    from repro.fleet.ipc import ProcessWorkerPool
    init = {"history": store,
            "fleet": {"watermark_delay": 1},
            "replay": {}}
    pool = ProcessWorkerPool(1, init)
    try:
        pool.submit(("no-such-kind", "job-bad", [], None, False))
        with pytest.raises(RuntimeError, match="job-bad"):
            pool.drain()
    finally:
        pool.close()


def test_max_pending_rows_forced_close(world):
    """The per-job memory cap: a stalled watermark cannot buffer
    unboundedly — oldest pending steps are force-closed (newest always
    survives), the forced closes are counted, and the behaviour is
    deterministic run-to-run."""
    prog, store = world
    batch = ClusterSimulator(N, prog, seed=71,
                             injections=SCENARIOS["gc"]).run_batch(6)
    chunks = _step_chunks(batch)

    def run(cap):
        # watermark_delay so large no step EVER closes on its own: only
        # the cap (or the final flush) can close anything
        mux = FleetMultiplexer(FleetConfig(watermark_delay=100,
                                           max_pending_rows=cap),
                               history=store)
        mux.add_job("job-m", EngineConfig(backend="dense-train",
                                          num_ranks=N))
        for c in chunks:
            mux.ingest("job-m", c)
        job = mux.job("job-m")
        buffered = job.store.buffered_rows
        pending = list(job.store.pending_steps())
        forced = mux.telemetry.counter("fleet.forced_closes",
                                       job="job-m").value
        anoms = [_sig(fa.anomaly) for fa in mux.finalize()]
        return buffered, pending, forced, anoms

    b0, p0, f0, _ = run(None)
    assert f0 == 0 and len(p0) == len(chunks)      # uncapped: all pending
    cap = max(len(c) for c in chunks) + 1
    b1, p1, f1, a1 = run(cap)
    assert f1 >= 1
    assert b1 <= cap or len(p1) == 1      # cap held (newest step is exempt)
    assert p1[-1] == max(c.step[0] for c in chunks)  # newest never forced
    assert run(cap) == (b1, p1, f1, a1)              # deterministic


def test_shared_interner_merge_tables():
    """Worker intern tables merge deterministically: ids for known
    strings are stable, new strings append in table order."""
    si = SharedInterner()
    assert si.intern_name("alpha") == 0
    assert si.intern_group("g0") == 0
    si.merge_tables(["beta", "alpha", "gamma"], ["g1", "g0"])
    assert si.names == ["alpha", "beta", "gamma"]
    assert si.groups == ["g0", "g1"]
    si.merge_tables(["gamma", "delta"], [])
    assert si.names == ["alpha", "beta", "gamma", "delta"]


def test_telemetry_absorb():
    """absorb() lands a worker snapshot on live parent handles: counters
    add (zero-valued series still materialize), gauges last-write-win,
    extra_tags re-tag the incoming series."""
    from repro.core.telemetry import TelemetryRegistry
    worker = TelemetryRegistry()
    worker.counter("fleet.late_rows", job="a").inc(3)
    worker.counter("fleet.zero", job="a")
    worker.gauge("fleet.watermark_lag", job="a").set(2.0)
    parent = TelemetryRegistry()
    parent.counter("fleet.late_rows", job="a").inc(1)
    parent.absorb(worker.snapshot())
    assert parent.counter("fleet.late_rows", job="a").value == 4
    assert parent.counter("fleet.zero", job="a").value == 0
    assert parent.gauge("fleet.watermark_lag", job="a").value == 2.0
    parent.absorb(worker.snapshot(), extra_tags={"shard": "1"})
    assert parent.counter("fleet.late_rows", job="a",
                          shard="1").value == 3


def test_daemon_attach_fleet_and_idempotent_stop():
    mux = FleetMultiplexer(FleetConfig(watermark_delay=0))
    d = TracingDaemon(DaemonConfig(rank=0, drain_interval=0.01,
                                   hang_timeout=1e9))
    d.attach_fleet(mux, "live-job")
    assert mux.job("live-job").daemon is d
    d.attach()
    for s in range(2):
        d.step_begin(s)
        d.record_span(EventKind.KERNEL_COMPUTE, "k", 0.0, 1.0, flops=5.0)
        d.step_end(tokens=16)
    time.sleep(0.2)
    d.stop()
    d.stop()                       # idempotent: second stop is a no-op
    mux.close()                    # stops daemons again, then finalizes
    st = mux.stats()["live-job"]
    assert st["events"] >= 4 and st["ranks"] == 1
    assert st["steps_evaluated"] >= 1
