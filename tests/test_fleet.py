"""Fleet subsystem tests (ISSUE 2).

Covered:
  * incremental-vs-batch equivalence: the multiplexer's per-step
    evaluation must produce byte-identical anomalies to a terminal
    ``evaluate_all`` on the concatenated batch, per job — including the
    hang path and with other (overlapping-name) jobs in the same fleet;
  * chunked-vs-line-by-line JSONL decoder equality on the same file;
  * tolerant decode of truncated/corrupt trailing lines (+ skip count);
  * shared-interning correctness across jobs with overlapping op names;
  * watermark semantics and late-event accounting;
  * directory replay through the multiplexer;
  * daemon ``attach_fleet`` seam and idempotent ``stop()``.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.columnar import EventBatch
from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind, TraceEvent
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import (FleetConfig, FleetMultiplexer, FleetReplayer,
                         SharedInterner)

N = 32


@pytest.fixture(scope="module")
def world():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng0 = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    for seed in range(3):
        eng0.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(4))
    eng0.learn_healthy()
    return prog, store


def _sig(a):
    """Byte-level anomaly signature: rendered line + canonical evidence."""
    return (str(a), json.dumps(a.evidence, sort_keys=True, default=str))


def _step_chunks(batch):
    order, uniq, bounds = batch.step_index()
    return [batch.take(order[bounds[i]:bounds[i + 1]])
            for i in range(uniq.size)]


SCENARIOS = {
    "healthy": [],
    "gc": [Injection(kind="gc", duration=0.02, period_ops=5)],
    "underclock": [Injection(kind="underclock", ranks=(5,), factor=2.5,
                             start_step=3)],
    "jitter": [Injection(kind="network_jitter", factor=3.0, start_step=3)],
    "hang": [Injection(kind="hang", ranks=(7,), at_step=2)],
}


def test_incremental_matches_batch_per_job(world):
    """Every job's streamed anomalies == terminal evaluate_all, even with
    the jobs multiplexed into ONE fleet with shared interning."""
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    oracle, batches = {}, {}
    for name, inj in SCENARIOS.items():
        batch = ClusterSimulator(N, prog, seed=7,
                                 injections=inj).run_batch(6)
        batches[name] = batch
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(batch)
        oracle[name] = [_sig(a) for a in eng.evaluate_all()]
        mux.add_job(name, EngineConfig(backend="dense-train", num_ranks=N))
    # interleave the jobs' per-step chunks round-robin (concurrent streams)
    pending = {name: _step_chunks(b) for name, b in batches.items()}
    while any(pending.values()):
        for name, chunks in pending.items():
            if chunks:
                mux.ingest(name, chunks.pop(0))
    got = {name: [] for name in SCENARIOS}
    for fa in mux.poll() + mux.finalize():
        got[fa.job_id].append(_sig(fa.anomaly))
    for name in SCENARIOS:
        assert got[name] == oracle[name], name
    assert oracle["healthy"] == []          # no cross-job contamination
    assert any(oracle[k] for k in ("gc", "underclock", "jitter", "hang"))


def test_engine_evaluate_new_steps_matches_evaluate_all(world):
    prog, store = world
    batch = ClusterSimulator(N, prog, seed=7,
                             injections=SCENARIOS["gc"]).run_batch(5)
    bulk = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    bulk.ingest_batch(batch)
    expect = [_sig(a) for a in bulk.evaluate_all()]
    inc = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    got = []
    for i, chunk in enumerate(_step_chunks(batch)):
        inc.ingest_batch(chunk)
        got.extend(inc.evaluate_new_steps(upto=i))  # watermark: step < max
    got.extend(inc.evaluate_new_steps())            # flush
    got.extend(inc.check_hangs())
    assert [_sig(a) for a in got] == expect


def test_chunked_jsonl_decoder_equals_line_by_line(world, tmp_path):
    prog, _ = world
    batch = ClusterSimulator(N, prog, seed=3).run_batch(3)
    path = str(tmp_path / "job.jsonl")
    batch.write_jsonl(path)
    a = EventBatch.from_jsonl(path)
    b = EventBatch.from_jsonl_chunked(path, chunk_bytes=4096, max_workers=3)
    assert len(a) == len(b) == len(batch)
    assert a.names == b.names and a.groups == b.groups
    for col in ("kind", "name_id", "rank", "issue_ts", "start_ts", "end_ts",
                "step", "nbytes", "tokens", "group_id"):
        assert np.array_equal(getattr(a, col), getattr(b, col)), col
    assert np.array_equal(a.flops, b.flops, equal_nan=True)
    assert a.extra == b.extra


def test_from_jsonl_skips_corrupt_trailing_lines(tmp_path):
    path = str(tmp_path / "killed.jsonl")
    evs = [TraceEvent(EventKind.STEP, f"step_{i}", 0, i, i, i + 1, step=i,
                      meta={"tokens": 8}) for i in range(5)]
    EventBatch.from_events(evs).write_jsonl(path)
    with open(path, "a") as f:
        f.write('not json at all\n')
        f.write('{"k":"step","n":"torn')       # truncated mid-write
    with pytest.warns(UserWarning, match="skipped 2"):
        batch, skipped = EventBatch.from_jsonl(path, with_skip_count=True)
    assert skipped == 2 and len(batch) == 5
    with pytest.warns(UserWarning, match="skipped 2"):
        batch2, skipped2 = EventBatch.from_jsonl_chunked(
            path, chunk_bytes=64, with_skip_count=True)
    assert skipped2 == 2 and len(batch2) == 5
    assert batch2.to_events() == batch.to_events()


def test_shared_interning_across_jobs():
    """Jobs with overlapping op names share one id space losslessly."""
    interner = SharedInterner()
    ev_a = [TraceEvent(EventKind.KERNEL_COMPUTE, n, r, 0.0, 0.0, 1.0, step=0,
                       meta={"flops": 1.0, "group": "dp"})
            for n in ("matmul", "attn", "norm") for r in range(2)]
    ev_b = [TraceEvent(EventKind.KERNEL_COMPUTE, n, r, 0.0, 0.0, 1.0, step=0,
                       meta={"flops": 1.0, "group": "pp"})
            for n in ("norm", "embed", "matmul") for r in range(2)]
    a = interner.adopt(EventBatch.from_events(ev_a))
    b = interner.adopt(EventBatch.from_events(ev_b))
    assert a.names is interner.names and b.names is interner.names
    assert interner.names == ["matmul", "attn", "norm", "embed"]
    assert interner.groups == ["dp", "pp"]
    # same string -> same id across jobs
    assert a.name_id[0] == b.name_id[4] == interner.names.index("matmul")
    # adoption is lossless row-wise
    assert [e.name for e in a.to_events()] == [e.name for e in ev_a]
    assert [e.name for e in b.to_events()] == [e.name for e in ev_b]
    assert [e.meta.get("group") for e in b.to_events()] == ["pp"] * 6
    # shared-interning concat needs no remap and keeps the shared tables
    m = EventBatch.concat([a, b])
    assert m.names is interner.names
    assert [e.name for e in m.to_events()] == \
        [e.name for e in ev_a] + [e.name for e in ev_b]


def test_watermark_and_late_events(world):
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    mux.add_job("j", EngineConfig(backend="dense-train", num_ranks=N))
    batch = ClusterSimulator(N, prog, seed=5).run_batch(4)
    chunks = _step_chunks(batch)
    mux.ingest("j", chunks[0])
    assert mux.job("j").evaluated == set()        # watermark holds step 0
    mux.ingest("j", chunks[1])
    assert mux.job("j").evaluated == {0}          # step 1 closed step 0
    mux.ingest("j", chunks[0])                    # stale re-delivery
    assert mux.job("j").late_events == len(chunks[0])
    mux.ingest("j", chunks[2])
    mux.ingest("j", chunks[3])
    mux.finalize("j")
    assert mux.job("j").evaluated == {0, 1, 2, 3}
    st = mux.stats()["j"]
    assert st["events"] == len(batch) + len(chunks[0])
    assert st["late_events"] == len(chunks[0])


def test_replay_directory_matches_direct_oracle(world, tmp_path):
    """Replaying recorded JSONL logs through the fleet = diagnosing the
    decoded batches directly (same rounding, same anomalies)."""
    prog, store = world
    logdir = tmp_path / "logs"
    os.makedirs(logdir)
    jobs = {"jobA-gc": SCENARIOS["gc"], "jobB-healthy": []}
    for job_id, inj in jobs.items():
        b = ClusterSimulator(N, prog, seed=7, injections=inj).run_batch(5)
        b.write_jsonl(str(logdir / f"{job_id}.jsonl"))
    oracle = {}
    for job_id in jobs:
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(EventBatch.from_jsonl(str(logdir / f"{job_id}.jsonl")))
        oracle[job_id] = [_sig(a) for a in eng.evaluate_all()]
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    for job_id in jobs:
        mux.add_job(job_id, EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux, chunk_bytes=1 << 16).replay_dir(str(logdir))
    got = {j: [] for j in jobs}
    for fa in mux.poll():
        got[fa.job_id].append(_sig(fa.anomaly))
    assert stats.files == 2 and stats.skipped_lines == 0
    assert stats.events == sum(s["events"] for s in mux.stats().values())
    for job_id in jobs:
        assert got[job_id] == oracle[job_id], job_id
    assert got["jobB-healthy"] == []


def test_concurrent_ingest_threads(world):
    """Jobs fed from separate threads (the daemon deployment shape) still
    match their single-threaded oracles — per-job locks + locked shared
    interner/stream."""
    import threading
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    oracle = {}
    work = {}
    for name in ("gc", "underclock", "jitter"):
        batch = ClusterSimulator(N, prog, seed=7,
                                 injections=SCENARIOS[name]).run_batch(6)
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(batch)
        oracle[name] = [_sig(a) for a in eng.evaluate_all()]
        mux.add_job(name, EngineConfig(backend="dense-train", num_ranks=N))
        work[name] = _step_chunks(batch)
    threads = [threading.Thread(
        target=lambda n=name: [mux.ingest(n, c) for c in work[n]])
        for name in work]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    got = {name: [] for name in work}
    for fa in mux.finalize():
        got[fa.job_id].append(_sig(fa.anomaly))
    for name in work:
        assert got[name] == oracle[name], name


def test_single_rank_suspect_does_not_declare_fleet_hang():
    """The hang threshold uses the job-wide rank count (engine config),
    not the ranks seen so far — one daemon's first drain containing a
    HANG_SUSPECT must not latch a majority hang on a 64-rank job."""
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1))
    mux.add_job("big", EngineConfig(backend="dense-train", num_ranks=64))
    sus = TraceEvent(EventKind.HANG_SUSPECT, "hang_suspect", 7,
                     30.0, 30.0, 30.0, step=0,
                     meta={"stack": ["train_step", "allreduce"]})
    mux.ingest("big", [sus])
    assert not mux.job("big").hang_reported
    assert mux.poll() == []
    # a majority of the configured ranks reporting DOES declare it
    mux.ingest("big", [
        TraceEvent(EventKind.HANG_SUSPECT, "hang_suspect", r,
                   30.0, 30.0, 30.0, step=0,
                   meta={"stack": ["train_step", "allreduce"]})
        for r in range(32)])
    assert mux.job("big").hang_reported
    anoms = mux.poll()
    assert len(anoms) == 1 and anoms[0].anomaly.kind == "hang"


def test_daemon_attach_fleet_and_idempotent_stop():
    mux = FleetMultiplexer(FleetConfig(watermark_delay=0))
    d = TracingDaemon(DaemonConfig(rank=0, drain_interval=0.01,
                                   hang_timeout=1e9))
    d.attach_fleet(mux, "live-job")
    assert mux.job("live-job").daemon is d
    d.attach()
    for s in range(2):
        d.step_begin(s)
        d.record_span(EventKind.KERNEL_COMPUTE, "k", 0.0, 1.0, flops=5.0)
        d.step_end(tokens=16)
    time.sleep(0.2)
    d.stop()
    d.stop()                       # idempotent: second stop is a no-op
    mux.close()                    # stops daemons again, then finalizes
    st = mux.stats()["live-job"]
    assert st["events"] >= 4 and st["ranks"] == 1
    assert st["steps_evaluated"] >= 1
