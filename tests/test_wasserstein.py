"""W1 distance vs scipy + healthy-threshold learning."""
import numpy as np
import pytest
from scipy.stats import wasserstein_distance

from repro.core.wasserstein import healthy_threshold, normalized_w1, w1_distance


@pytest.mark.parametrize("na,nb", [(100, 100), (100, 37), (8, 500)])
def test_matches_scipy(rng, na, nb):
    a = rng.standard_normal(na) * 3 + 1
    b = rng.standard_normal(nb)
    assert w1_distance(a, b) == pytest.approx(
        wasserstein_distance(a, b), rel=1e-9)


def test_identity_and_shift():
    a = np.linspace(0, 1, 50)
    assert w1_distance(a, a) == 0.0
    assert w1_distance(a, a + 2.0) == pytest.approx(2.0)


def test_healthy_threshold_margin(rng):
    runs = [rng.uniform(0, 1, 200) for _ in range(4)]
    thr = healthy_threshold(runs, margin=1.5)
    # every healthy pair is under the threshold by construction
    for i in range(4):
        for j in range(4):
            assert normalized_w1(runs[i], runs[j]) <= thr + 1e-12
    # a stalled (compressed) distribution exceeds it
    stalled = rng.uniform(0, 0.05, 200)
    assert normalized_w1(stalled, np.concatenate(runs)) > thr
