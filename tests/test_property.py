"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra (requirements-dev.txt)")
pytest.importorskip("scipy", reason="dev extra (requirements-dev.txt)")

from hypothesis import given, settings, strategies as st
from scipy.stats import wasserstein_distance

from repro.core.events import EventKind, TraceEvent
from repro.core.inspecting import diagnose_ring
from repro.core.stack import reconstruct_stacks
from repro.core.wasserstein import w1_distance
from repro.data.masks import (mask_fast_linear, mask_naive_quadratic,
                              materialize_from_starts, segment_ids_from_docs)
from repro.optim.adamw import _q_dec, _q_enc

finite = st.floats(-1e6, 1e6, allow_nan=False, width=32)


@given(st.lists(finite, min_size=1, max_size=60),
       st.lists(finite, min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_w1_matches_scipy(a, b):
    ours = w1_distance(a, b)
    ref = wasserstein_distance(a, b)
    assert abs(ours - ref) <= 1e-6 * max(1.0, abs(ref))


@given(st.lists(finite, min_size=1, max_size=40))
@settings(max_examples=30, deadline=None)
def test_w1_identity_and_symmetry(a):
    b = [x + 1.0 for x in a]
    assert w1_distance(a, a) == 0.0
    assert abs(w1_distance(a, b) - w1_distance(b, a)) < 1e-9


@given(st.integers(3, 200), st.integers(0, 199), st.integers(0, 50),
       st.integers(1, 4))
@settings(max_examples=80, deadline=None)
def test_ring_diagnosis_always_contains_fault(n, fault, s0, fifo):
    fault = fault % n
    total = 2 * (n - 1)
    s0 = min(s0, max(total - fifo - 1, 0))
    progress = np.zeros(n, np.int64)
    for d in range(n):
        r = (fault + d) % n
        if d == 0:
            progress[r] = min(s0 + fifo, total)
        elif d == 1:
            progress[r] = s0
        else:
            progress[r] = min(s0 + min(d - 1, fifo), total)
    d = diagnose_ring(progress)
    assert fault in d.machines


@given(st.lists(st.integers(1, 20), min_size=1, max_size=6))
@settings(max_examples=40, deadline=None)
def test_mask_generators_equivalent(doc_lens):
    L = sum(doc_lens)
    seg = segment_ids_from_docs(doc_lens, L)
    np.testing.assert_array_equal(
        mask_naive_quadratic(seg),
        materialize_from_starts(mask_fast_linear(seg)))


@given(st.lists(finite, min_size=1, max_size=600))
@settings(max_examples=40, deadline=None)
def test_int8_quantizer_error_bound(xs):
    import jax.numpy as jnp
    x = jnp.asarray(np.asarray(xs, np.float32).reshape(1, -1))
    dec = _q_dec(_q_enc(x), x.shape)
    bound = float(np.abs(xs).max()) / 127.0 + 1e-5
    assert float(np.abs(np.asarray(dec) - np.asarray(x)).max()) <= \
        bound * 1.02


@given(st.lists(st.tuples(st.floats(0, 100, allow_nan=False),
                          st.floats(0.001, 10, allow_nan=False)),
                min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_stack_reconstruction_well_nested(spans):
    """For arbitrary span sets, every event's callpath prefix chain exists
    and parents always contain children (issue-time containment)."""
    evs = []
    for i, (start, dur) in enumerate(spans):
        evs.append(TraceEvent(EventKind.PY_API, f"s{i}", 0,
                              start, start, start + dur))
    reconstruct_stacks(evs)
    by_name = {e.name: e for e in evs}
    for e in evs:
        parent = e.meta.get("parent")
        if parent is None:
            continue
        p = by_name[parent]
        assert p.issue_ts <= e.issue_ts + 1e-9
        assert p.end_ts >= e.issue_ts - 1e-9
