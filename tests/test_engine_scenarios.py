"""End-to-end diagnostic-engine scenarios on the 32-rank cluster simulator:
every paper anomaly class must be detected AND routed to the right team
with no cross-firing (Table 1)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.history import HistoryStore
from repro.core.timeline import ClusterSimulator, Injection, program_from_config

N = 32


@pytest.fixture(scope="module")
def world():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng0 = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    for seed in range(3):
        sim = ClusterSimulator(N, prog, seed=seed)
        eng0.ingest_all(sim.run(4))
    eng0.learn_healthy()
    return prog, store


def _diagnose(world, injections, steps=6, seed=7, shapes=None):
    prog, store = world
    eng = DiagnosticEngine(EngineConfig(
        backend="dense-train", num_ranks=N,
        kernel_shapes=shapes or {}), store)
    sim = ClusterSimulator(N, prog, seed=seed, injections=injections)
    eng.ingest_all(sim.run(steps))
    if sim.hang:
        return [eng.diagnose_hang(sim.hang.stacks, sim.hang.ring_progress)]
    return eng.evaluate_all()


def test_healthy_clean(world):
    assert _diagnose(world, []) == []


def test_gc_stall_routed_to_algorithm(world):
    a = _diagnose(world, [Injection(kind="gc", duration=0.02, period_ops=5)])
    hit = [x for x in a if x.metric == "issue_latency"]
    assert hit and all(x.team.value == "algorithm" for x in hit)
    assert any("GC" in x.root_cause for x in hit)


def test_sync_stall_detected(world):
    a = _diagnose(world, [Injection(kind="sync_after_comm")])
    hit = [x for x in a if x.metric == "issue_latency"]
    assert hit and "synchronization" in hit[0].root_cause


def test_case3_dataloader_v_inter(world):
    a = _diagnose(world, [Injection(kind="slow_dataloader", factor=1.0,
                                    duration=2.0)])
    assert any(x.metric == "v_inter" and x.team.value == "algorithm"
               for x in a)
    assert not any(x.team.value == "infrastructure" for x in a)


def test_table5_minority_kernels(world):
    a = _diagnose(world, [Injection(kind="minority_kernels", factor=0.35)])
    assert any(x.metric == "v_minority"
               and x.team.value == "infrastructure" for x in a)
    assert not any(x.metric == "issue_latency" for x in a)


def test_failslow_underclock_routed_to_ops(world):
    a = _diagnose(world, [Injection(kind="underclock", ranks=(5,),
                                    factor=2.5, start_step=3)])
    hit = [x for x in a if x.kind == "fail_slow"]
    assert hit and 5 in hit[0].ranks
    assert not any(x.kind == "regression" for x in a)


def test_failslow_network_jitter(world):
    a = _diagnose(world, [Injection(kind="network_jitter", factor=3.0,
                                    start_step=3)])
    assert any(x.kind == "fail_slow" and x.metric == "bandwidth" for x in a)
    assert not any(x.kind == "regression" for x in a)


def test_case2_flops_layout_advice(world):
    shapes = {f"ffn_matmul[{g}]": (8192, 8484) for g in range(8)}
    a = _diagnose(world, [Injection(kind="slow_compute",
                                    op_match="ffn_matmul", factor=2.88)],
                  shapes=shapes)
    hit = [x for x in a if x.metric == "flops"]
    assert hit and "pad" in hit[0].root_cause
    assert hit[0].evidence["layout_advice"]["padded_dims"] == [8512]


def test_comm_hang_o1_inspection(world):
    a = _diagnose(world, [Injection(kind="hang", ranks=(11,), at_step=2)])
    assert a[0].kind == "hang"
    assert a[0].metric == "intra_kernel_inspecting"
    assert 11 in a[0].ranks


def test_noncomm_hang_stack_analysis(world):
    a = _diagnose(world, [Injection(kind="hang", ranks=(3,), at_step=2,
                                    at_op=0,
                                    meta={"noncomm_crash": True})])
    assert a[0].metric == "call_stack_analysis" and a[0].ranks == [3]


def test_paper_accuracy_batch(world):
    """113-job style batch: healthy + injected; measure FP/TP (paper §7.3:
    9 TP, 2 FP over 113 jobs)."""
    prog, store = world
    tp = fp = fn = 0
    for seed in range(8):
        a = _diagnose(world, [], seed=100 + seed, steps=4)
        fp += 1 if any(x.kind == "regression" for x in a) else 0
    regressions = [
        [Injection(kind="gc", duration=0.02, period_ops=5)],
        [Injection(kind="sync_after_comm")],
        [Injection(kind="minority_kernels", factor=0.4)],
        [Injection(kind="slow_dataloader", duration=2.5)],
    ]
    for seed, inj in enumerate(regressions):
        a = _diagnose(world, inj, seed=200 + seed)
        if any(x.kind == "regression" for x in a):
            tp += 1
        else:
            fn += 1
    assert fp == 0, "healthy runs must not raise regressions"
    assert tp == len(regressions) and fn == 0
