"""Per-arch smoke tests: reduced configs, one forward + one train step on
CPU, shapes + no NaNs (assignment requirement), plus prefill/decode
consistency against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.models import build_model
from repro.models.layers import Policy
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

FP32 = Policy(jnp.float32, jnp.float32)


def _extras(cfg, B):
    if cfg.family == "vlm":
        return {"vision_embeds": jnp.ones(
            (B, cfg.vision_tokens, cfg.vision_d), jnp.float32)}
    return {}


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_no_nan(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, policy=FP32)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    logits, *_ = model.apply(params, tokens, **_extras(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nan(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, policy=FP32)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    B, S = 2, 32
    rng = jax.random.PRNGKey(2)
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    extras = _extras(cfg, B)

    def loss_fn(p):
        loss, _ = model.loss(p, batch, **extras)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    params2, opt2, m = adamw_update(grads, opt, params, opt_cfg, 1e-3)
    loss2 = loss_fn(params2)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-0.5b", "dbrx-132b",
                                  "mamba2-780m", "zamba2-2.7b",
                                  "llama-3.2-vision-11b"])
def test_prefill_decode_matches_forward(arch):
    """decode_step at position S must reproduce apply()'s logits[S]."""
    cfg = get_reduced(arch)
    model = build_model(cfg, policy=FP32)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0,
                              cfg.vocab_size)
    extras = _extras(cfg, B)
    full_logits, *_ = model.apply(params, toks, **extras)

    cache = model.init_cache(B, S + 8)
    last, cache = model.prefill(params, toks[:, :S], cache, **extras)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    step_logits, cache = model.decode_step(params, toks[:, S:S + 1], cache,
                                           jnp.int32(S))
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, S]),
                               rtol=2e-3, atol=2e-3)
