"""Scenario library + matrix scorer: library invariants, grading
semantics on synthetic anomalies, scorer math on synthetic cells, and a
few real cells on the smallest config (the full sweep is CI's
``benchmarks.scenarios --quick``)."""
import pytest

from repro.core.anomaly import Anomaly, Team
from repro.scenarios import (FAULT_KINDS, SCENARIOS, SCENARIOS_BY_NAME,
                             CellResult, anomaly_key, run_cell,
                             score_matrix, scenarios_for)
from repro.scenarios.runner import _grade


# --------------------------------------------------------------------- #
# library invariants
# --------------------------------------------------------------------- #
def test_taxonomy_breadth():
    assert len(FAULT_KINDS) >= 8, FAULT_KINDS
    assert len(SCENARIOS) >= 12
    assert any(s.healthy for s in SCENARIOS)
    assert sum("l4" in s.tags for s in SCENARIOS) >= 5


def test_every_fault_scenario_is_labelled():
    for s in SCENARIOS:
        if s.healthy:
            continue
        assert s.truth.expect, s.name
        assert s.truth.team in ("operations", "algorithm",
                                "infrastructure"), s.name
        for k in s.truth.expect + s.truth.allowed:
            assert ":" in k, (s.name, k)
        assert s.inject(1.0, 32), s.name


def test_injections_scale_with_step_time():
    gc = SCENARIOS_BY_NAME["gc_stall"]
    small = gc.inject(0.3, 32)[0].duration
    large = gc.inject(30.0, 32)[0].duration
    assert large == pytest.approx(100 * small)


def test_moe_scenario_gated_by_family():
    class Cfg:
        family = "llama"
    class Moe:
        family = "moe"
    names = {s.name for s in scenarios_for(Cfg())}
    assert "moe_straggler" not in names
    assert "moe_straggler" in {s.name for s in scenarios_for(Moe())}
    assert "healthy" in names


# --------------------------------------------------------------------- #
# grading semantics (synthetic anomalies — no simulation)
# --------------------------------------------------------------------- #
def _anom(kind, metric, team, step=5, ranks=()):
    return Anomaly(kind=kind, metric=metric, team=Team(team),
                   root_cause="x", step=step, ranks=list(ranks))


def test_grade_catch():
    scn = SCENARIOS_BY_NAME["gpu_underclock"]   # expects fail_slow tput @5
    a = _anom("fail_slow", "throughput", "operations", step=4, ranks=(5,))
    c = _grade(scn, "cfg", [a])
    assert c.ok and c.caught and c.first_step == 4
    assert anomaly_key(a) in c.fired


def test_grade_miss_and_false_positive():
    scn = SCENARIOS_BY_NAME["gpu_underclock"]
    c = _grade(scn, "cfg", [_anom("regression", "flops", "infrastructure")])
    assert not c.ok and not c.caught
    assert c.false_keys == ("regression:flops",)


def test_grade_wrong_team_and_ranks():
    scn = SCENARIOS_BY_NAME["gpu_underclock"]
    wrong_team = _anom("fail_slow", "throughput", "algorithm",
                       step=4, ranks=(5,))
    assert not _grade(scn, "cfg", [wrong_team]).team_ok
    wrong_rank = _anom("fail_slow", "throughput", "operations",
                       step=4, ranks=(9,))
    assert not _grade(scn, "cfg", [wrong_rank]).ranks_ok


def test_grade_onset_violation():
    scn = SCENARIOS_BY_NAME["gpu_underclock"]   # onset_step=3
    early = _anom("fail_slow", "throughput", "operations",
                  step=1, ranks=(5,))
    assert not _grade(scn, "cfg", [early]).onset_ok
    # hang anomalies carry step=-1: never an onset violation
    scn_h = SCENARIOS_BY_NAME["comm_hang"]
    h = _anom("hang", "intra_kernel_inspecting", "operations",
              step=-1, ranks=(11,))
    assert _grade(scn_h, "cfg", [h]).ok


def test_grade_allowed_secondary_not_penalized():
    scn = SCENARIOS_BY_NAME["checkpoint_write_storm"]
    a = [_anom("regression", "issue_latency", "infrastructure", step=3),
         _anom("fail_slow", "throughput", "operations", step=4)]
    c = _grade(scn, "cfg", a)
    assert c.ok and c.false_keys == ()


def test_grade_healthy_any_firing_is_false():
    scn = SCENARIOS_BY_NAME["healthy"]
    assert _grade(scn, "cfg", []).ok
    c = _grade(scn, "cfg", [_anom("regression", "flops", "infrastructure")])
    assert not c.ok and c.false_keys == ("regression:flops",)


# --------------------------------------------------------------------- #
# scorer math (synthetic cells)
# --------------------------------------------------------------------- #
def _cell(scenario, healthy=False, fired=(), false_keys=(), caught=True,
          team_ok=True, ranks_ok=True, onset_ok=True, anomalies=0):
    return CellResult(scenario=scenario, config="cfg", healthy=healthy,
                      fired=tuple(fired), false_keys=tuple(false_keys),
                      caught=caught, team_ok=team_ok, ranks_ok=ranks_ok,
                      onset_ok=onset_ok, first_step=-1, anomalies=anomalies)


def test_score_matrix_counts():
    cells = [
        _cell("gpu_underclock", fired=("fail_slow:throughput",),
              anomalies=1),                                    # TP
        _cell("ecc_throttle", fired=("regression:flops",),
              false_keys=("regression:flops",), caught=False,
              anomalies=1),                                    # FN + FP
        _cell("healthy", healthy=True, fired=("regression:v_inter",),
              false_keys=("regression:v_inter",), anomalies=1),  # FP
    ]
    s = score_matrix(cells)
    tput = s["detectors"]["fail_slow:throughput"]
    assert tput["tp"] == 1 and tput["fn"] == 1      # FN charged to expect[0]
    assert tput["recall"] == 0.5
    assert s["detectors"]["regression:flops"]["fp"] == 1
    assert s["detectors"]["regression:v_inter"]["fp"] == 1
    assert s["missed"] == ["ecc_throttle@cfg"]
    assert s["false_positive_cells"] == sorted(
        {"ecc_throttle@cfg", "healthy@cfg"})
    assert s["micro_recall"] == 0.5
    assert s["micro_precision"] == pytest.approx(1 / 3)


def test_score_matrix_perfect():
    cells = [_cell("gpu_underclock", fired=("fail_slow:throughput",),
                   anomalies=1),
             _cell("healthy", healthy=True)]
    s = score_matrix(cells)
    assert s["micro_precision"] == 1.0 and s["micro_recall"] == 1.0
    assert not s["missed"] and not s["misrouted"]


def test_score_matrix_misrouted():
    cells = [_cell("gpu_underclock", fired=("fail_slow:throughput",),
                   team_ok=False, anomalies=1)]
    assert score_matrix(cells)["misrouted"] == ["gpu_underclock@cfg"]


# --------------------------------------------------------------------- #
# real cells on the smallest config (sanity, not the full sweep)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["healthy", "gc_stall", "ecc_throttle",
                                  "comm_hang"])
def test_real_cell(name):
    c = run_cell(SCENARIOS_BY_NAME[name], "qwen2-0.5b")
    assert c.ok, (name, c)


def test_moe_straggler_cell():
    c = run_cell(SCENARIOS_BY_NAME["moe_straggler"], "dbrx-132b")
    assert c.ok and "regression:flops" in c.fired
