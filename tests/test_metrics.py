"""Aggregated metrics ①-⑤ on hand-built event timelines."""
import numpy as np

from repro.core.events import EventKind, TraceEvent
from repro.core.metrics import aggregate_step


def _ev(kind, name, rank, i, s, e, **meta):
    return TraceEvent(kind, name, rank, i, s, e, step=0, meta=meta)


def test_throughput_and_voids():
    # rank timeline: dataloader [0,1], kernels [1,2],[2,3],[4,5] (gap 3-4
    # with next issued at 2.5 -> minority), step [0,6]
    evs = {0: [
        _ev(EventKind.STEP, "step_0", 0, 0, 0, 6.0, tokens=600),
        _ev(EventKind.DATALOADER, "dl", 0, 0.0, 0.0, 1.0, tokens=600),
        _ev(EventKind.KERNEL_COMPUTE, "a", 0, 0.9, 1.0, 2.0, flops=100.0),
        _ev(EventKind.KERNEL_COMPUTE, "b", 0, 1.0, 2.0, 3.0, flops=100.0),
        _ev(EventKind.KERNEL_COMPUTE, "c", 0, 2.5, 4.0, 5.0, flops=100.0),
    ]}
    m = aggregate_step(evs, 0)
    assert m.throughput == 100.0  # 600 tokens / 6 s
    assert m.t_inter == 1.0  # dataloader gap
    assert abs(m.v_inter - 1.0 / 6.0) < 1e-9
    # minority gap: [3,4] with c issued at 2.5 <= 3.0
    assert abs(m.v_minority - 1.0 / 5.0) < 1e-9
    assert m.flops["a"][0] == 100.0


def test_issue_stall_gap_not_minority():
    # gap caused by LATE ISSUE (issue 3.5 > prev end 3.0) is NOT minority
    evs = {0: [
        _ev(EventKind.STEP, "step_0", 0, 0, 0, 6.0, tokens=60),
        _ev(EventKind.KERNEL_COMPUTE, "a", 0, 0.5, 1.0, 3.0, flops=1.0),
        _ev(EventKind.KERNEL_COMPUTE, "b", 0, 3.5, 4.0, 5.0, flops=1.0),
    ]}
    m = aggregate_step(evs, 0)
    assert m.v_minority == 0.0
    assert m.issue_latencies.size == 0  # no comm kernels


def test_bandwidth_last_issuer():
    # paper: bandwidth uses the LAST-starting rank's start timestamp
    evs = {
        0: [_ev(EventKind.KERNEL_COMM, "ar", 0, 0.0, 2.0, 4.0, bytes=8e9)],
        1: [_ev(EventKind.KERNEL_COMM, "ar", 1, 1.9, 2.0, 4.0, bytes=8e9)],
    }
    m = aggregate_step(evs, 0)
    assert abs(m.bandwidth["ar"] - 8e9 / 2.0) < 1e-6
    assert m.issue_latencies.size == 2


def test_overlap_flagging():
    # compute kernel overlapped >50% by comm must be excluded from FLOPS
    evs = {0: [
        _ev(EventKind.KERNEL_COMPUTE, "mm", 0, 0.0, 1.0, 3.0, flops=10.0),
        _ev(EventKind.KERNEL_COMM, "a2a", 0, 0.0, 1.5, 3.0, bytes=100),
    ]}
    m = aggregate_step(evs, 0)
    assert "mm" in m.flops_overlapped


def test_api_span_accumulation():
    evs = {0: [
        _ev(EventKind.GC, "gc.collect", 0, 0.0, 0.0, 0.5),
        _ev(EventKind.GC, "gc.collect", 0, 1.0, 1.0, 1.5),
        _ev(EventKind.SYNC, "sync", 0, 2.0, 2.0, 2.1),
    ]}
    m = aggregate_step(evs, 0)
    assert abs(m.api_spans["gc.collect"] - 1.0) < 1e-9
    assert abs(m.api_spans["sync"] - 0.1) < 1e-9
