import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real single device.
# Multi-device sharding tests run in subprocesses that set the flag
# themselves (see test_multidevice.py).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
