"""Attention implementation equivalence incl. the folded-causal perf path
and the flash-style custom VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (chunked_attention, decode_attention,
                                    direct_attention,
                                    folded_causal_attention)


@pytest.fixture
def qkv(rng):
    B, S, H, KV, hd = 2, 256, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    return q, k, v


def test_folded_equals_direct(qkv):
    q, k, v = qkv
    for depth in (1, 2, 3):
        o = folded_causal_attention(q, k, v, depth=depth)
        r = direct_attention(q, k, v, causal=True)
        np.testing.assert_allclose(o, r, rtol=3e-4, atol=3e-4)


def test_chunked_gradients_match_direct(qkv):
    q, k, v = qkv

    def loss_chunked(q, k, v):
        return jnp.sum(jnp.tanh(chunked_attention(
            q, k, v, causal=True, q_chunk=64, kv_chunk=64)))

    def loss_direct(q, k, v):
        return jnp.sum(jnp.tanh(direct_attention(q, k, v, causal=True)))

    g1 = jax.grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_direct, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=3e-3, atol=3e-3)


def test_decode_matches_direct_row(qkv):
    q, k, v = qkv
    pos = 100
    o_full = direct_attention(q[:, :pos + 1], k[:, :pos + 1],
                              v[:, :pos + 1], causal=True)
    o_dec = decode_attention(q[:, pos:pos + 1], k, v, jnp.int32(pos))
    np.testing.assert_allclose(o_dec[:, 0], o_full[:, pos],
                               rtol=3e-4, atol=3e-4)
