"""Per-kernel shape/dtype sweeps against pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.fused_norm.ops import fused_residual_rmsnorm
from repro.kernels.fused_norm.ref import fused_ref
from repro.kernels.padded_matmul.ops import padded_matmul
from repro.kernels.padded_matmul.ref import matmul_ref
from repro.kernels.ring_reduce.ops import ring_combine
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

TOLS = {jnp.float32: dict(rtol=3e-4, atol=3e-4),
        jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 256, 4, 2, 64), (2, 384, 6, 3, 32),
                                   (1, 128, 2, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, shape, dtype, causal):
    B, S, H, KV, hd = shape
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), dtype)
    o = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    r = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mkn", [(128, 128, 128), (64, 100, 212),
                                 (256, 384, 212), (32, 848, 96)])
def test_padded_matmul_sweep(rng, mkn, dtype):
    M, K, N = mkn
    a = jnp.asarray(rng.standard_normal((M, K)), dtype)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype)
    tol = dict(TOLS[dtype])
    tol["atol"] = max(tol["atol"], 2e-3 * K ** 0.5)
    np.testing.assert_allclose(np.asarray(padded_matmul(a, b), np.float32),
                               np.asarray(matmul_ref(a, b), np.float32),
                               **tol)


@pytest.mark.parametrize("shape", [(1, 64, 2, 8, 8), (2, 128, 3, 16, 8),
                                   (1, 96, 1, 32, 16)])
def test_ssd_scan_sweep(rng, shape):
    B, L, H, P, N = shape
    chunk = 32 if L % 32 == 0 else L
    x = jnp.asarray(rng.standard_normal((B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    r = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(y, r, rtol=4e-4, atol=4e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(256, 64), (512, 96), (128, 256)])
def test_fused_norm_sweep(rng, shape, dtype):
    R, D = shape
    x = jnp.asarray(rng.standard_normal((R, D)), dtype)
    r = jnp.asarray(rng.standard_normal((R, D)), dtype)
    s = jnp.asarray(rng.standard_normal((D,)), dtype)
    y, h = fused_residual_rmsnorm(x, r, s, block_r=128)
    yr, hr = fused_ref(x, r, s)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOLS[dtype])
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("C,block", [(4096, 512), (2048, 1024), (1024, 1024)])
def test_ring_combine(rng, C, block):
    a = jnp.asarray(rng.standard_normal(C), jnp.float32)
    b = jnp.asarray(rng.standard_normal(C), jnp.float32)
    out, prog = ring_combine(a, b, block=block)
    np.testing.assert_allclose(out, a + b, rtol=1e-6)
    np.testing.assert_array_equal(prog, np.arange(1, C // block + 1))
