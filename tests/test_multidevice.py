"""Multi-device sharding semantics, run in a subprocess with 8 fake devices
(the main test process must keep seeing ONE device — assignment rule)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.collectives import (ring_all_reduce,
                                            compressed_psum_local)
    from repro.parallel.compat import shard_map
    from repro.parallel.pipeline import pipeline_apply

    mesh = make_test_mesh(data=2, model=4)

    # ---- progress-instrumented ring all-reduce == psum ---------------- #
    x = jnp.arange(32.0).reshape(8, 4)
    res, prog = jax.jit(
        lambda v: ring_all_reduce(v, mesh, axis="model"))(x)
    # input replicated over model => allreduce sums 4 copies
    np.testing.assert_allclose(np.asarray(res), 4 * np.asarray(x), rtol=1e-6)
    prog = np.asarray(prog)
    assert prog.shape == (4, 6) and prog.min() == 1  # 2*(N-1) steps done
    print("ring_all_reduce OK")

    # ---- int8 compressed psum with error feedback --------------------- #
    def body(v):
        out, err = compressed_psum_local(v, "model", None)
        return out, err
    xs = jnp.linspace(-2, 2, 64).reshape(8, 8)
    out, err = jax.jit(shard_map(
        body, mesh=mesh, in_specs=P(), out_specs=(P(), P("model")),
        check_vma=False))(xs)
    np.testing.assert_allclose(np.asarray(out), 4 * np.asarray(xs),
                               rtol=0.05, atol=0.05)
    print("compressed_psum OK")

    # ---- GPipe pipeline == sequential application --------------------- #
    smesh = make_test_mesh(data=1, model=1)  # placeholder
    from repro.launch.mesh import _mk
    pmesh = _mk((4,), ("stage",))
    def stage_fn(w, x):
        return jnp.tanh(x @ w)
    ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.5
    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 16))  # M=8 mb=4
    out = pipeline_apply(stage_fn, ws, xs, pmesh, axis="stage")
    ref = xs
    for i in range(4):
        ref = jnp.tanh(ref @ ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print("pipeline OK")

    # ---- MoE expert-parallel == local oracle --------------------------- #
    from repro.configs import get_reduced
    from repro.models.moe import moe_apply, moe_init
    cfg = get_reduced("dbrx-132b")  # 4 experts top-2
    params = moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, cfg.d_model))
    y_local, aux_l = moe_apply(params, x, cfg, mesh=None)
    y_shard, aux_s = jax.jit(
        lambda p, v: moe_apply(p, v, cfg, mesh=mesh))(params, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_shard),
                               rtol=2e-4, atol=2e-4)
    print("moe EP OK")
""")


def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    for marker in ("ring_all_reduce OK", "compressed_psum OK",
                   "pipeline OK", "moe EP OK"):
        assert marker in r.stdout, r.stdout + r.stderr
