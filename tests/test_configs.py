"""Assigned-architecture configs: exact published shapes + param counts."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, cells, get_config,
                           get_reduced, list_archs)

EXPECT = {
    "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                        num_kv_heads=32, d_ff=10240, vocab_size=32000,
                        ssm_state=64),
    "dbrx-132b": dict(num_layers=40, d_model=6144, num_heads=48,
                      num_kv_heads=8, d_ff=10752, vocab_size=100352,
                      num_experts=16, experts_per_token=4),
    "arctic-480b": dict(num_layers=35, d_model=7168, num_heads=56,
                        num_kv_heads=8, d_ff=4864, vocab_size=32000,
                        num_experts=128, experts_per_token=2),
    "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128,
                        num_kv_heads=8, d_ff=53248, vocab_size=128256),
    "llama3.2-1b": dict(num_layers=16, d_model=2048, num_heads=32,
                        num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "qwen2-0.5b": dict(num_layers=24, d_model=896, num_heads=14,
                       num_kv_heads=2, d_ff=4864, vocab_size=151936,
                       qkv_bias=True),
    "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                      num_kv_heads=8, d_ff=29568, vocab_size=152064,
                      qkv_bias=True),
    "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32,
                           num_kv_heads=32, d_ff=8192, vocab_size=2048),
    "mamba2-780m": dict(num_layers=48, d_model=1536, num_heads=0,
                        d_ff=0, vocab_size=50280, ssm_state=128),
    "llama-3.2-vision-11b": dict(num_layers=40, d_model=4096, num_heads=32,
                                 num_kv_heads=8, d_ff=14336,
                                 vocab_size=128256),
}

# analytic param counts should land near the advertised sizes
PARAM_BANDS = {
    "zamba2-2.7b": (2.0e9, 3.4e9),
    "dbrx-132b": (118e9, 145e9),
    "arctic-480b": (430e9, 520e9),
    "llama3-405b": (380e9, 430e9),
    "llama3.2-1b": (1.0e9, 1.6e9),
    "qwen2-0.5b": (0.4e9, 0.65e9),
    "qwen2-72b": (65e9, 80e9),
    "musicgen-large": (2.8e9, 3.7e9),  # MusicGen-large is 3.3B
    "mamba2-780m": (0.6e9, 0.95e9),
    "llama-3.2-vision-11b": (9e9, 13e9),
}


@pytest.mark.parametrize("arch", sorted(EXPECT))
def test_exact_config(arch):
    cfg = get_config(arch)
    for k, v in EXPECT[arch].items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


@pytest.mark.parametrize("arch", sorted(PARAM_BANDS))
def test_param_count_band(arch):
    lo, hi = PARAM_BANDS[arch]
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"


def test_moe_active_params_smaller():
    for arch in ("dbrx-132b", "arctic-480b"):
        cfg = get_config(arch)
        assert cfg.active_param_count() < 0.6 * cfg.param_count()


def test_cells_and_skips():
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    run = [c for c in all_cells if not c[2]]
    skipped = [c for c in all_cells if c[2]]
    # long_500k runs only for ssm/hybrid
    assert {(a, s) for a, s, _ in skipped} == {
        (a, "long_500k") for a in ASSIGNED_ARCHS
        if not get_config(a).sub_quadratic}
    assert len(skipped) == 8 and len(run) == 32


def test_reduced_configs_are_small():
    for arch in list_archs():
        r = get_reduced(arch)
        assert r.param_count() < 5e6, arch
        assert r.family == get_config(arch).family


def test_shapes():
    assert SHAPES["train_4k"].tokens == 4096 * 256
    assert SHAPES["decode_32k"].tokens == 128  # one token per sequence
    assert SHAPES["long_500k"].seq_len == 524288
