"""Stack reconstruction: interval containment -> call paths."""
from repro.core.events import EventKind, TraceEvent
from repro.core.stack import reconstruct_stacks


def _ev(kind, name, i, s, e, rank=0):
    return TraceEvent(kind, name, rank, i, s, e, step=0)


def test_nesting():
    evs = [
        _ev(EventKind.STEP, "step", 0.0, 0.0, 10.0),
        _ev(EventKind.PY_API, "outer", 1.0, 1.0, 6.0),
        _ev(EventKind.PY_API, "inner", 2.0, 2.0, 3.0),
        _ev(EventKind.KERNEL_COMPUTE, "mm", 2.5, 7.0, 8.0),  # issued in inner
        _ev(EventKind.PY_API, "later", 7.0, 7.0, 8.0),
    ]
    reconstruct_stacks(evs)
    by = {e.name: e for e in evs}
    assert by["outer"].meta["callpath"] == "step/outer"
    assert by["inner"].meta["callpath"] == "step/outer/inner"
    # kernel nests where it was ISSUED, not where it executed
    assert by["mm"].meta["callpath"] == "step/outer/inner/mm"
    assert by["later"].meta["callpath"] == "step/later"


def test_per_rank_isolation():
    evs = [
        _ev(EventKind.STEP, "s0", 0.0, 0.0, 10.0, rank=0),
        _ev(EventKind.PY_API, "a", 1.0, 1.0, 2.0, rank=1),
    ]
    reconstruct_stacks(evs)
    assert evs[1].meta["callpath"] == "a"  # rank 1 has no enclosing span
