"""Trace-storage layer: codec registry, FCS round-trip losslessness,
corruption hardening, rotation, memmap lifetime, mixed-format replay,
and the process-pool JSONL decoder.

The FCS contract is stronger than JSONL's: EventBatch -> FCS ->
EventBatch is BYTE-equivalent (JSONL rounds timestamps to 1e-6), and
fleet diagnosis replayed from FCS must be byte-equivalent to the JSONL
replay of the same events.
"""
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.columnar import EventBatch, EventBatchBuilder
from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind, TraceEvent
from repro.core.history import HistoryStore
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro import store
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer
from repro.fleet.store import SharedInterner

N = 32

COLS = ("kind", "name_id", "rank", "issue_ts", "start_ts", "end_ts",
        "step", "flops", "nbytes", "tokens", "group_id")


def _prog():
    cfg = get_config("llama-20b-paper")
    return program_from_config(cfg, num_chips=N)


def _sim(injections=None, seed=9, steps=3):
    return ClusterSimulator(N, _prog(), seed=seed,
                            injections=injections or []).run_batch(steps)


@pytest.fixture(scope="module")
def history():
    """Learned healthy profile so replayed diagnosis has detectors armed."""
    hist = HistoryStore()
    learner = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), hist)
    for seed in range(3):
        learner.ingest_batch(
            ClusterSimulator(N, _prog(), seed=seed).run_batch(4))
    learner.learn_healthy()
    return hist


def _assert_batches_byte_equal(a: EventBatch, b: EventBatch):
    for c in COLS:
        ca, cb = getattr(a, c), getattr(b, c)
        assert ca.dtype == cb.dtype, c
        assert ca.tobytes() == cb.tobytes(), c
    assert a.names == b.names
    assert a.groups == b.groups
    assert a.extra == b.extra


# --------------------------------------------------------------------- #
# registry / detection
# --------------------------------------------------------------------- #
def test_registry_and_detection(tmp_path):
    assert store.get_codec("jsonl").name == "jsonl"
    assert store.get_codec("fcs").name == "fcs"
    with pytest.raises(KeyError):
        store.get_codec("parquet")
    assert store.codec_for_path("x.jsonl").name == "jsonl"
    assert store.codec_for_path("x.fcs").name == "fcs"
    # extensionless files resolve by content sniff
    b = _sim()
    fcs = str(tmp_path / "noext_fcs")
    store.write_trace(b, fcs, codec="fcs")
    jl = str(tmp_path / "noext_jsonl")
    store.write_trace(b, jl, codec="jsonl")
    assert store.codec_for_path(fcs).name == "fcs"
    assert store.codec_for_path(jl).name == "jsonl"


# --------------------------------------------------------------------- #
# FCS round-trips
# --------------------------------------------------------------------- #
def test_fcs_roundtrip_byte_equivalent(tmp_path):
    b = _sim([Injection(kind="gc", duration=0.25, period_ops=5)])
    path = str(tmp_path / "t.fcs")
    nbytes = store.write_fcs(b, path)
    assert nbytes == os.path.getsize(path)
    _assert_batches_byte_equal(b, store.read_fcs(path))


def test_fcs_roundtrip_empty_batch(tmp_path):
    path = str(tmp_path / "e.fcs")
    store.write_fcs(EventBatch.empty(), path)
    rb = store.read_fcs(path)
    assert len(rb) == 0 and rb.names == [] and rb.groups == []
    _assert_batches_byte_equal(EventBatch.empty(), rb)


def test_fcs_roundtrip_meta_heavy(tmp_path):
    """Tuples, nested structures, per-row and shared dicts, hang stacks —
    the meta shapes JSONL can only approximate survive FCS exactly."""
    bld = EventBatchBuilder()
    shared = {"shape": (8, 16, 32), "layout": "R,C"}
    for r in range(6):
        bld.append_event(TraceEvent(
            EventKind.KERNEL_COMPUTE, "mm", r, 1.0, 1.25, 2.0, step=0,
            meta={"flops": 1e12, **shared}))
        bld.append_event(TraceEvent(
            EventKind.HANG_SUSPECT, "hang_suspect", r, 3.0, 3.0, 3.0,
            step=1, meta={"stack": [f"f{i}" for i in range(3)],
                          "silent_s": 31.5,
                          "nested": {"a": [1, (2, 3)], "b": None}}))
    b = bld.build()
    path = str(tmp_path / "m.fcs")
    store.write_fcs(b, path)
    rb = store.read_fcs(path)
    _assert_batches_byte_equal(b, rb)
    # tuple-typed meta survives as a tuple (JSONL would give a list)
    row = next(r for r, d in rb.extra.items() if "shape" in d)
    assert rb.extra[row]["shape"] == (8, 16, 32)
    assert isinstance(rb.extra[row]["shape"], tuple)


def test_fcs_roundtrip_shared_interner_batches(tmp_path):
    """Batches adopted onto a fleet-shared interner reference fleet-wide
    id tables; their FCS round-trip must preserve the remapped ids."""
    interner = SharedInterner()
    a = interner.adopt(_sim(seed=1, steps=2))
    b = interner.adopt(_sim([Injection(kind="network_jitter", factor=3.0,
                                       start_step=1)], seed=2, steps=2))
    assert a.names is b.names          # shared tables
    for i, batch in enumerate((a, b)):
        path = str(tmp_path / f"s{i}.fcs")
        store.write_fcs(batch, path)
        _assert_batches_byte_equal(batch, store.read_fcs(path))


def test_fcs_multi_segment_append_and_chunks(tmp_path):
    b1, b2 = _sim(seed=1, steps=2), _sim(seed=2, steps=2)
    path = str(tmp_path / "t.fcs")
    store.write_fcs(b1, path)
    store.write_fcs(b2, path)
    chunks = [c for c, _ in store.iter_trace_chunks(path)]
    assert len(chunks) == 2
    _assert_batches_byte_equal(b1, chunks[0])
    _assert_batches_byte_equal(b2, chunks[1])
    whole = store.read_fcs(path)
    assert len(whole) == len(b1) + len(b2)


def test_fcs_memmap_survives_writer_and_handle_close(tmp_path):
    """Decoded views hold the memory map alive: reads stay valid after
    the writer is gone and the reader's file handles are closed."""
    b = _sim(seed=4)
    path = str(tmp_path / "t.fcs")
    store.write_fcs(b, path)
    rb = store.read_fcs(path)          # all handles closed on return
    ts = rb.start_ts                   # zero-copy memmap view
    assert ts.base is not None         # really a view, not a copy
    import gc
    gc.collect()
    assert float(ts.sum()) == pytest.approx(float(b.start_ts.sum()))
    assert rb.to_events()[0].name == b.to_events()[0].name


# --------------------------------------------------------------------- #
# FCS v2 (compressed archival segments)
# --------------------------------------------------------------------- #
def test_fcs2_roundtrip_byte_equivalent_and_smaller(tmp_path):
    b = _sim([Injection(kind="gc", duration=0.25, period_ops=5)], steps=3)
    p1, p2 = str(tmp_path / "t.fcs"), str(tmp_path / "t.fcs2")
    n1 = store.write_fcs(b, p1)
    n2 = store.write_trace(b, p2, codec="fcs2")
    assert n2 == os.path.getsize(p2)
    _assert_batches_byte_equal(b, store.read_trace(p2))
    # v1 and v2 decode to the same batch, and v2 is the archival win
    _assert_batches_byte_equal(store.read_fcs(p1), store.read_fcs(p2))
    assert n2 < n1 * 0.6, f"v2 {n2}B not meaningfully smaller than v1 {n1}B"


def test_fcs2_roundtrip_meta_heavy(tmp_path):
    """Tuples, nested meta, hang stacks survive v2 exactly as v1 — the
    interning/meta blobs are stored plain, only slabs are compressed."""
    bld = EventBatchBuilder()
    shared = {"shape": (8, 16, 32), "layout": "R,C"}
    for r in range(6):
        bld.append_event(TraceEvent(
            EventKind.KERNEL_COMPUTE, "mm", r, 1.0, 1.25, 2.0, step=0,
            meta={"flops": 1e12, **shared}))
        bld.append_event(TraceEvent(
            EventKind.HANG_SUSPECT, "hang_suspect", r, 3.0, 3.0, 3.0,
            step=1, meta={"stack": [f"f{i}" for i in range(3)],
                          "nested": {"a": [1, (2, 3)], "b": None}}))
    b = bld.build()
    path = str(tmp_path / "m.fcs2")
    store.write_trace(b, path, codec="fcs2")
    rb = store.read_trace(path)
    _assert_batches_byte_equal(b, rb)
    row = next(r for r, d in rb.extra.items() if "shape" in d)
    assert rb.extra[row]["shape"] == (8, 16, 32)
    assert isinstance(rb.extra[row]["shape"], tuple)


def test_fcs2_empty_batch_and_tiny_slabs(tmp_path):
    """Empty/tiny segments take the stored (uncompressed) slab path."""
    path = str(tmp_path / "e.fcs2")
    store.write_trace(EventBatch.empty(), path, codec="fcs2")
    _assert_batches_byte_equal(EventBatch.empty(), store.read_trace(path))


def test_fcs_mixed_version_segments_in_one_file(tmp_path):
    """A daemon restarted with a different spill config appends v2
    segments after v1 ones; the reader dispatches per segment."""
    b1, b2 = _sim(seed=1, steps=2), _sim(seed=2, steps=2)
    path = str(tmp_path / "t.fcs")
    store.write_fcs(b1, path)                    # v1 segment
    store.write_fcs(b2, path, version=2)         # v2 segment, same file
    chunks = [c for c, _ in store.iter_trace_chunks(path)]
    assert len(chunks) == 2
    _assert_batches_byte_equal(b1, chunks[0])
    _assert_batches_byte_equal(b2, chunks[1])


def test_fcs2_truncated_tail_keeps_leading_segments(tmp_path):
    b1, b2 = _sim(seed=1, steps=1), _sim(seed=2, steps=1)
    path = str(tmp_path / "t.fcs2")
    store.write_trace(b1, path, codec="fcs2")
    n1 = os.path.getsize(path)
    store.write_trace(b2, path, codec="fcs2")
    n2 = os.path.getsize(path)
    with open(path, "r+b") as f:       # kill the writer mid-slab
        f.truncate(n1 + (n2 - n1) // 2)
    got = []
    with pytest.raises(store.CodecError) as ei:
        for chunk, _ in store.iter_trace_chunks(path):
            got.append(chunk)
    assert ei.value.offset == n1 and "truncated" in str(ei.value)
    assert len(got) == 1
    _assert_batches_byte_equal(b1, got[0])


def test_fcs2_bitflip_in_compressed_slab_is_codec_error(tmp_path):
    """Bit-rot inside a compressed slab must surface as CodecError (the
    zlib/zstd checksum or the inflated-length check catches it)."""
    b = _sim(seed=5, steps=2)
    path = str(tmp_path / "rot.fcs2")
    store.write_trace(b, path, codec="fcs2")
    raw = bytearray(open(path, "rb").read())
    raw[-40:] = b"\xff" * 40
    open(path, "wb").write(bytes(raw))
    with pytest.raises(store.CodecError):
        store.read_trace(path, codec="fcs")
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1))
    stats = FleetReplayer(mux).replay_dir(str(tmp_path))
    assert stats.corrupt_files == 1


def test_fcs2_zstd_absent_falls_back_to_zlib(tmp_path, monkeypatch):
    """Without the zstandard package an explicit 'zstd' request warns
    once (counted) and writes zlib-backed v2 — never fails the spill."""
    from repro.store import compress as comp
    monkeypatch.setattr(comp, "_zstd", None)
    monkeypatch.setattr(comp, "zstd_fallbacks", 0)
    b = _sim(seed=3, steps=2)
    path = str(tmp_path / "zb.fcs2")
    with pytest.warns(UserWarning, match="zstandard is not installed"):
        store.write_fcs(b, path, version=2, compression="zstd")
    assert comp.zstd_fallbacks == 1
    store.write_fcs(b, path, version=2, compression="zstd")  # warns once
    assert comp.zstd_fallbacks == 2
    _assert_batches_byte_equal(
        EventBatch.concat([b, b]), store.read_trace(path))


def test_fcs2_zstd_slab_without_library_is_codec_error(tmp_path,
                                                       monkeypatch):
    """Reading a genuinely zstd-compressed slab on a box without the
    package must raise a clear CodecError, not crash."""
    from repro.store import compress as comp
    from repro.store.fcs import _DIRENT2, _HEADER
    b = _sim(seed=3, steps=2)
    path = str(tmp_path / "z.fcs2")
    store.write_fcs(b, path, version=2)
    # rewrite every compressed dirent's backend byte to claim zstd
    raw = bytearray(open(path, "rb").read())
    _, _, _, _, _, names_len, groups_len, extra_len = \
        _HEADER.unpack_from(raw, 0)
    blob = names_len + groups_len + extra_len
    dir_off = _HEADER.size + blob + (-blob % 8)
    changed = 0
    for i in range(13):
        ent = dir_off + i * _DIRENT2.size
        col_id, enc, dt, cb, clen, rlen = _DIRENT2.unpack_from(raw, ent)
        if cb & comp.COMP_MASK == comp.COMP_ZLIB:
            _DIRENT2.pack_into(raw, ent, col_id, enc, dt,
                               comp.COMP_ZSTD | (cb & comp.FLAG_SHUFFLE),
                               clen, rlen)
            changed += 1
    assert changed > 0
    open(path, "wb").write(bytes(raw))
    monkeypatch.setattr(comp, "_zstd", None)
    with pytest.raises(store.CodecError, match="zstandard"):
        store.read_trace(path, codec="fcs")


@pytest.mark.skipif(not store.have_zstd(), reason="zstandard not installed")
def test_fcs2_zstd_backend_roundtrip(tmp_path):
    b = _sim(seed=3, steps=2)
    path = str(tmp_path / "zs.fcs2")
    store.write_fcs(b, path, version=2, compression="zstd")
    _assert_batches_byte_equal(b, store.read_trace(path))


def test_fcs2_daemon_spill_knob(tmp_path):
    """DaemonConfig.log_compression implies the archival v2 spill."""
    log = str(tmp_path / "d.fcs")
    d = TracingDaemon(DaemonConfig(rank=3, log_path=log,
                                   log_compression="zlib",
                                   log_compression_level=9,
                                   reconstruct=False))
    for step in range(3):
        d.step_begin(step)
        d.record_span(EventKind.KERNEL_COMPUTE, "mm", 0.1 * step,
                      0.1 * step + 0.05, flops=1e9)
        d.step_end(tokens=128)
        d._flush()
    assert d.bytes_logged > 0
    from repro.store.fcs import _HEADER
    with open(log, "rb") as f:
        magic, version = _HEADER.unpack_from(f.read(_HEADER.size))[:2]
    assert magic == b"FCS1" and version == 2
    batches = [store.read_trace(p) for p in d.log_paths]
    assert sum(len(x) for x in batches) == d.events_emitted == 6


# --------------------------------------------------------------------- #
# corruption hardening
# --------------------------------------------------------------------- #
def test_fcs_bad_magic_raises_with_location(tmp_path):
    path = str(tmp_path / "bad.fcs")
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\0" * 60)
    with pytest.raises(store.CodecError) as ei:
        store.read_fcs(path)
    assert ei.value.path == path and ei.value.offset == 0
    assert "magic" in str(ei.value)


def test_fcs_bad_version_raises(tmp_path):
    b = _sim(seed=5, steps=1)
    path = str(tmp_path / "v.fcs")
    store.write_fcs(b, path)
    raw = bytearray(open(path, "rb").read())
    raw[4:6] = (99).to_bytes(2, "little")
    open(path, "wb").write(bytes(raw))
    with pytest.raises(store.CodecError, match="version"):
        store.read_fcs(path)


def test_fcs_truncated_tail_raises_and_keeps_leading_segments(tmp_path):
    b1, b2 = _sim(seed=1, steps=1), _sim(seed=2, steps=1)
    path = str(tmp_path / "t.fcs")
    store.write_fcs(b1, path)
    n1 = os.path.getsize(path)
    store.write_fcs(b2, path)
    n2 = os.path.getsize(path)
    with open(path, "r+b") as f:       # kill the writer mid-slab
        f.truncate(n1 + (n2 - n1) // 2)
    got = []
    with pytest.raises(store.CodecError) as ei:
        for chunk, _ in store.iter_trace_chunks(path):
            got.append(chunk)
    assert ei.value.offset == n1 and "truncated" in str(ei.value)
    assert len(got) == 1               # intact leading segment survived
    _assert_batches_byte_equal(b1, got[0])


def test_replay_dir_skips_and_counts_corrupt(tmp_path):
    good = _sim(seed=1, steps=3)
    store.write_fcs(good, str(tmp_path / "job-good.fcs"))
    # bad magic: whole file skipped
    with open(tmp_path / "job-bad.fcs", "wb") as f:
        f.write(b"XXXX" + b"\0" * 100)
    # truncated tail: first segment replays, tail counted
    tr = str(tmp_path / "job-trunc.fcs")
    store.write_fcs(_sim(seed=2, steps=3), tr)
    n1 = os.path.getsize(tr)
    store.write_fcs(_sim(seed=3, steps=3), tr)
    with open(tr, "r+b") as f:
        f.truncate(os.path.getsize(tr) - 33)
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1))
    stats = FleetReplayer(mux).replay_dir(str(tmp_path))
    assert stats.corrupt_files == 2
    assert stats.per_job["job-good"] == len(good)
    assert stats.per_job["job-trunc"] > 0      # leading segment replayed
    assert "job-bad" not in stats.per_job


def test_fcs_corrupt_slab_length_is_codec_error(tmp_path):
    """A corrupted directory length field must raise, not silently shift
    every later column: frombuffer reads from the slab start regardless
    of the declared length while the cursor advances BY it."""
    from repro.store.fcs import _DIRENT, _HEADER
    b = _sim(seed=5, steps=2)
    path = str(tmp_path / "len.fcs")
    store.write_fcs(b, path)
    raw = bytearray(open(path, "rb").read())
    # find the first dirent with a non-zero payload and halve its length
    _, _, _, _, _, names_len, groups_len, extra_len = \
        _HEADER.unpack_from(raw, 0)
    blob = names_len + groups_len + extra_len
    dir_off = _HEADER.size + blob + (-blob % 8)
    for i in range(13):
        ent = dir_off + i * _DIRENT.size
        col_id, enc, dt, z, plen = _DIRENT.unpack_from(raw, ent)
        if plen > 1:
            _DIRENT.pack_into(raw, ent, col_id, enc, dt, z, plen // 2)
            break
    open(path, "wb").write(bytes(raw))
    with pytest.raises(store.CodecError, match="slab length"):
        store.read_fcs(path)


def test_fcs_bitflip_in_dict_slab_is_codec_error(tmp_path):
    """Bit-rot inside a DICT codes slab must surface as CodecError (the
    replay skip-and-count contract), not IndexError."""
    b = _sim(seed=5, steps=2)
    path = str(tmp_path / "rot.fcs")
    store.write_fcs(b, path)
    raw = bytearray(open(path, "rb").read())
    raw[-40:] = b"\xff" * 40           # stomp the tail slab (extra codes)
    open(path, "wb").write(bytes(raw))
    with pytest.raises(store.CodecError):
        store.read_fcs(path)
    # and replay_dir survives it
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1))
    stats = FleetReplayer(mux).replay_dir(str(tmp_path))
    assert stats.corrupt_files == 1


# --------------------------------------------------------------------- #
# rotation
# --------------------------------------------------------------------- #
def test_segmented_writer_rotation_roundtrip(tmp_path):
    b = _sim(seed=7, steps=4)
    order, uniq, bounds = b.step_index()
    slices = [b.take(order[bounds[i]:bounds[i + 1]])
              for i in range(uniq.size)]
    base = str(tmp_path / "job-r.fcs")
    w = store.SegmentedTraceWriter(base, codec="fcs", rotate_bytes=1)
    for s in slices:                   # rotate_bytes=1: one file per write
        w.write(s)
    assert len(w.paths) == len(slices)
    assert w.paths[0] == base and ".seg001." in w.paths[1]
    assert all(store.job_id_for_path(p) == "job-r" for p in w.paths)
    whole = EventBatch.concat([store.read_fcs(p) for p in w.paths])
    assert len(whole) == len(b)
    assert whole.step.tolist() == b.step[order].tolist()
    assert np.array_equal(np.sort(whole.end_ts), np.sort(b.end_ts))


def test_segmented_writer_resumes_after_restart(tmp_path):
    """A restarted writer (daemon restart, same log_path) appends AFTER
    the last rotated piece instead of interleaving into old segments."""
    b = _sim(seed=7, steps=2)
    base = str(tmp_path / "job-r.fcs")
    w1 = store.SegmentedTraceWriter(base, codec="fcs", rotate_bytes=1)
    w1.write(b)
    w1.write(b)                        # -> job-r.fcs, job-r.seg001.fcs
    w2 = store.SegmentedTraceWriter(base, codec="fcs", rotate_bytes=1)
    assert w2.current_path == w1.paths[-1]     # resumed, not restarted
    w2.write(b)                        # current piece is full -> seg002
    assert w2.current_path.endswith(".seg002.fcs")
    sizes = {p: os.path.getsize(p) for p in w2.paths}
    assert len(sizes) == 3             # nothing interleaved into old files
    assert store.seg_index(w2.current_path) == 2
    assert store.seg_index(base) == 0


def test_replay_orders_rotated_segments_numerically(tmp_path):
    """seg1000 must replay after seg999 (lexicographic order would not)."""
    paths = [str(tmp_path / n) for n in
             ("job.fcs", "job.seg999.fcs", "job.seg1000.fcs")]
    assert sorted(paths, key=lambda p: store.seg_index(p)) == paths
    assert sorted(paths) != paths      # the bug a plain sort would have
    for step, p in enumerate(paths):
        bld = EventBatchBuilder()
        bld.append_event(TraceEvent(EventKind.STEP, f"step_{step}", 0,
                                    float(step), float(step),
                                    step + 0.5, step=step))
        store.write_fcs(bld.build(), p)
    mux = FleetMultiplexer(FleetConfig(watermark_delay=0))
    stats = FleetReplayer(mux).replay_dir(str(tmp_path))
    assert stats.per_job == {"job": 3}
    assert sorted(mux.job("job").evaluated) == [0, 1, 2]
    assert mux.job("job").late_events == 0     # in-order: nothing late


def test_daemon_spill_fcs_with_rotation(tmp_path):
    """Daemon spill through the FCS codec, one segment per drain,
    rotating by size.  Flushes are driven synchronously (the daemon
    thread is never started) so the drain-per-step layout is
    deterministic."""
    log = str(tmp_path / "d.fcs")
    d = TracingDaemon(DaemonConfig(rank=3, log_path=log, log_codec="fcs",
                                   log_rotate_bytes=512,
                                   reconstruct=False))
    for step in range(6):
        d.step_begin(step)
        d.record_span(EventKind.KERNEL_COMPUTE, "mm", 0.1 * step,
                      0.1 * step + 0.05, flops=1e9)
        d.step_end(tokens=128)
        d._flush()                     # one spill segment per step
    assert d.bytes_logged > 0
    assert len(d.log_paths) >= 2       # rotation kicked in
    batches = [store.read_fcs(p) for p in d.log_paths]
    total = sum(len(x) for x in batches)
    assert total == d.events_emitted == 12
    ranks = {int(r) for x in batches for r in x.ranks()}
    assert ranks == {3}
    steps = sorted(s for x in batches for s in x.steps())
    assert steps == list(range(6))


# --------------------------------------------------------------------- #
# mixed-format replay + diagnosis equivalence
# --------------------------------------------------------------------- #
def _fleet_logs(tmp_path, codecs):
    """Write the same two-job fleet under per-job codecs.  FCS sources
    are the JSONL-decoded batches, so both encodings carry identical
    values (JSONL rounds timestamps at write time)."""
    jobs = {
        "job-a": _sim([Injection(kind="gc", duration=0.05, period_ops=4)],
                      seed=1, steps=5),
        "job-b": _sim([Injection(kind="underclock", ranks=(5,), factor=2.5,
                                 start_step=2)], seed=2, steps=5),
    }
    d = tmp_path / "-".join(codecs.values())
    d.mkdir()
    for job, batch in jobs.items():
        jl = str(d / f"{job}.jsonl")
        store.write_trace(batch, jl)
        if codecs[job] == "fcs":
            rounded = store.read_jsonl(jl)
            os.remove(jl)
            store.write_fcs(rounded, str(d / f"{job}.fcs"))
    return str(d), jobs


def _replay_anomalies(logdir, history, **replayer_kw):
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=history)
    for job in ("job-a", "job-b"):
        mux.add_job(job, EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux, **replayer_kw).replay_dir(logdir)
    return stats, [str(a) for a in mux.poll()]


def test_mixed_dir_replay_diagnosis_byte_equivalent(tmp_path, history):
    dir_jsonl, jobs = _fleet_logs(tmp_path, {"job-a": "jsonl",
                                             "job-b": "jsonl"})
    dir_mixed, _ = _fleet_logs(tmp_path, {"job-a": "jsonl",
                                          "job-b": "fcs"})
    dir_fcs, _ = _fleet_logs(tmp_path, {"job-a": "fcs", "job-b": "fcs"})
    s_jsonl, a_jsonl = _replay_anomalies(dir_jsonl, history)
    s_mixed, a_mixed = _replay_anomalies(dir_mixed, history)
    s_fcs, a_fcs = _replay_anomalies(dir_fcs, history, chunk_bytes=1 << 16)
    total = sum(len(b) for b in jobs.values())
    assert s_jsonl.events == s_mixed.events == s_fcs.events == total
    assert a_jsonl == a_mixed == a_fcs          # byte-equivalent diagnosis
    assert a_fcs                                # and it found something


def test_fcs_step_aligned_streaming_matches_monolithic(tmp_path, history):
    """Segment streaming through the replayer must equal feeding the
    whole batch at once (watermark closes the same steps either way)."""
    batch = _sim([Injection(kind="gc", duration=0.3, period_ops=4)],
                 seed=6, steps=4)
    path = str(tmp_path / "job-x.fcs")
    store.write_fcs(batch, path)

    direct = FleetMultiplexer(FleetConfig(watermark_delay=1),
                              history=history)
    direct.add_job("job-x", EngineConfig(backend="dense-train", num_ranks=N))
    direct.ingest("job-x", batch)
    expect = [str(a) for a in direct.finalize()]

    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=history)
    mux.add_job("job-x", EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux).replay_dir(str(tmp_path))
    got = [str(a) for a in mux.poll()]
    assert stats.files == 1 and stats.events == len(batch)
    assert got == expect
    assert expect                      # the scenario actually alarms


# --------------------------------------------------------------------- #
# process-pool chunk decoding
# --------------------------------------------------------------------- #
def test_jsonl_process_executor_matches_thread(tmp_path):
    batch = _sim(seed=8, steps=3)
    path = str(tmp_path / "t.jsonl")
    store.write_trace(batch, path)
    # serial_below=0: force real chunking on this small file — the
    # auto-fallback would otherwise decode it in one serial pass
    thread = store.read_jsonl_chunked(path, chunk_bytes=1 << 14,
                                      serial_below=0)
    proc = store.read_jsonl_chunked(path, chunk_bytes=1 << 14,
                                    executor="process", max_workers=2,
                                    serial_below=0)
    _assert_batches_byte_equal(thread, proc)
    serial = store.read_jsonl_chunked(path, chunk_bytes=1 << 14)
    _assert_batches_byte_equal(thread, serial)   # fallback: same result
    with pytest.raises(ValueError, match="executor"):
        store.read_jsonl_chunked(path, executor="fiber")


def test_replayer_process_executor(tmp_path):
    batch = _sim(seed=8, steps=3)
    store.write_trace(batch, str(tmp_path / "job-p.jsonl"))
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1))
    mux.add_job("job-p", EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux, chunk_bytes=1 << 14, executor="process",
                          serial_below=0).replay_dir(str(tmp_path))
    assert stats.events == len(batch)
    assert len(mux.job("job-p").evaluated) > 0


# --------------------------------------------------------------------- #
# in-memory FCS bytes (the fleet IPC wire format)
# --------------------------------------------------------------------- #
def test_encode_decode_batch_bytes_roundtrip():
    batch = _sim(seed=9, steps=3)
    _assert_batches_byte_equal(
        store.decode_batch_bytes(store.encode_batch_bytes(batch)), batch)
    # version passthrough: v3 blobs carry (and verify) the stats block
    _assert_batches_byte_equal(
        store.decode_batch_bytes(store.encode_batch_bytes(batch, version=3)),
        batch)
    # a blob holding several appended segments decodes to their concat
    order, uniq, bounds = batch.step_index()
    parts = [batch.take(order[bounds[i]:bounds[i + 1]])
             for i in range(uniq.size)]
    got = store.decode_batch_bytes(
        b"".join(store.encode_batch_bytes(p, version=1) for p in parts))
    assert len(got) == len(batch)
    assert np.array_equal(np.sort(got.end_ts), np.sort(batch.end_ts))
    assert len(store.decode_batch_bytes(b"")) == 0


def test_is_sidecar_path():
    assert store.is_sidecar_path("/logs/job-a.fcs3" + store.ROLLUP_SUFFIX)
    assert store.is_sidecar_path("telemetry-000.json")
    assert not store.is_sidecar_path("/logs/job-a.jsonl")
    assert not store.is_sidecar_path("job.json")
    assert not store.is_sidecar_path("telemetry-abc.json")
