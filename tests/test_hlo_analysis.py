"""Scan-aware HLO analyzer: trip-count multiplication vs unrolled truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import HloAnalysis, analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_flops_match_unrolled():
    def body(x, _):
        return jnp.dot(x, x) + 1.0, None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=12)
        return jnp.sum(y)

    def unrolled(x):
        for _ in range(12):
            x = jnp.dot(x, x) + 1.0
        return jnp.sum(x)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fs = analyze_hlo(_compile(scanned, x).as_text())["flops"]
    fu = analyze_hlo(_compile(unrolled, x).as_text())["flops"]
    assert fs == fu == pytest.approx(12 * 2 * 128 ** 3)


def test_nested_scan_multiplies():
    def inner(x, _):
        return jnp.dot(x, x), None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=3)
        return y, None

    def fn(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f = analyze_hlo(_compile(fn, x).as_text())["flops"]
    assert f == pytest.approx(15 * 2 * 64 ** 3)


def test_traffic_scales_with_trip_count():
    def body(x, _):
        return x * 2.0 + 1.0, None

    def fn(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    t = analyze_hlo(_compile(fn, x).as_text())["traffic_bytes"]
    one_pass = 1024 * 1024 * 4
    assert t >= 10 * one_pass  # at least read+write per iteration
    assert t <= 80 * one_pass


def test_dot_flops_from_contracting_dims():
    def fn(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    f = analyze_hlo(_compile(fn, a, b).as_text())["flops"]
    assert f == pytest.approx(2 * 4 * 32 * 16 * 64)
