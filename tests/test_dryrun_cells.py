"""Dry-run smoke: two fast cells must lower+compile on BOTH production
meshes in a subprocess (512 forced devices stay out of this process)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args,
         "--out", "/tmp/dryrun_pytest"],
        env=env, cwd=ROOT, capture_output=True, text=True, timeout=timeout)


@pytest.mark.parametrize("extra", [[], ["--multi-pod"]])
def test_decode_cell_both_meshes(extra):
    r = _run(["--arch", "qwen2-0.5b", "--shape", "decode_32k", *extra])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK " in r.stdout


def test_hybrid_long_context_cell():
    r = _run(["--arch", "mamba2-780m", "--shape", "long_500k"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "dom=" in r.stdout


def test_main_process_still_single_device():
    import jax
    assert jax.device_count() == 1
