"""Trace archive + FCS v3 stats directory + telemetry plane (ISSUE 7).

Covered:
  * v3 stats-directory correctness: ``segment_stats`` reports the exact
    step/time/rank ranges, kind bits and per-column min/max of the rows
    written, and a v3 round-trip stays byte-equivalent;
  * predicate semantics: severity classes, span-intersection time
    matching, rank sets, the conservative segment test vs the exact row
    filter, and the v1/v2 "no stats => must decode" rule;
  * pruned reads over a MIXED v1/v2/v3 directory are byte-equivalent to
    the full-decode oracle while actually skipping v3 segments;
  * a truncated or bit-flipped stats block raises ``CodecError`` from
    both the stats iterator and the full decode — corruption can never
    silently mis-prune;
  * rollup cache staleness: a segment append re-rolls exactly the file
    it touched (fingerprint invalidation, counted in telemetry);
  * the telemetry snapshot covers daemon + multiplexer + replayer series
    and round-trips through the archive's JSON export;
  * ``FleetReplayer(predicate=...)`` accounts skipped segments/bytes;
  * anomaly queries, team filtering and the fleet-weather report.
"""
import os
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.anomaly import Team
from repro.core.columnar import EventBatch
from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind, TraceEvent
from repro.core.history import HistoryStore
from repro.core.telemetry import TelemetryRegistry
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro import store
from repro.archive import TraceArchive, format_fleet_weather
from repro.fleet import FleetConfig, FleetMultiplexer, FleetReplayer
from repro.store import Predicate, SegmentStats
from repro.store.base import CodecError
from repro.store.fcs import _DIRENT2, _HEADER, _parse_header, _stats_offset

N = 16

COLS = ("kind", "name_id", "rank", "issue_ts", "start_ts", "end_ts",
        "step", "flops", "nbytes", "tokens", "group_id")


def _assert_batches_byte_equal(a: EventBatch, b: EventBatch):
    for c in COLS:
        ca, cb = getattr(a, c), getattr(b, c)
        assert ca.dtype == cb.dtype, c
        assert ca.tobytes() == cb.tobytes(), c
    assert a.names == b.names
    assert a.groups == b.groups
    assert a.extra == b.extra


def _prog():
    cfg = get_config("llama-20b-paper")
    return program_from_config(cfg, num_chips=N)


@pytest.fixture(scope="module")
def world():
    prog = _prog()
    hist = HistoryStore()
    eng = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), hist)
    for seed in range(3):
        eng.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(4))
    eng.learn_healthy()
    return prog, hist


def _per_step_segments(b: EventBatch):
    order, uniq, bounds = b.step_index()
    return [b.take(order[bounds[i]:bounds[i + 1]])
            for i in range(uniq.size)]


def _write_archive(logdir, prog, *, steps=6, jobs=("job-a", "job-b"),
                   injections=None):
    """One rotated v3 file per job, one segment per step."""
    os.makedirs(logdir, exist_ok=True)
    for j, job in enumerate(jobs):
        inj = (injections or {}).get(job, [])
        b = ClusterSimulator(N, prog, seed=21 + j,
                             injections=inj).run_batch(steps)
        w = store.SegmentedTraceWriter(os.path.join(logdir, f"{job}.fcs3"),
                                       codec="fcs3", rotate_bytes=1)
        for sb in _per_step_segments(b):
            w.write(sb)


# --------------------------------------------------------------------- #
# stats directory correctness
# --------------------------------------------------------------------- #
def test_v3_stats_match_written_rows(tmp_path):
    evs = [
        TraceEvent(EventKind.KERNEL_COMPUTE, "mm", rank=3, issue_ts=10.0,
                   start_ts=10.5, end_ts=11.0, step=7,
                   meta={"flops": 2e9}),
        TraceEvent(EventKind.KERNEL_COMM, "ar", rank=9, issue_ts=11.0,
                   start_ts=11.25, end_ts=12.5, step=9,
                   meta={"bytes": 4096}),
        TraceEvent(EventKind.GC, "gc", rank=5, issue_ts=9.0,
                   start_ts=9.75, end_ts=9.9),  # unattributed (step=-1)
    ]
    b = EventBatch.from_events(evs)
    path = str(tmp_path / "t.fcs3")
    store.write_fcs(b, path, version=3)

    _assert_batches_byte_equal(b, store.read_fcs(path))

    (st,) = list(store.segment_stats(path))
    assert st.version == 3 and st.has_stats and st.n_rows == 3
    # step range is over attributed rows only
    assert (st.step_min, st.step_max) == (7, 9)
    assert st.ts_min == pytest.approx(9.75)    # min start_ts
    assert st.ts_max == pytest.approx(12.5)    # max end_ts
    assert (st.rank_min, st.rank_max) == (3, 9)
    assert set(st.kinds()) == {EventKind.KERNEL_COMPUTE,
                               EventKind.KERNEL_COMM, EventKind.GC}
    # per-column min/max: rank col 2, flops col 7 (NaN-excluded),
    # nbytes col 8 (NO_INT-excluded: only the comm row carries bytes)
    assert st.column_range(2) == (3, 9)
    assert st.column_range(7) == pytest.approx((2e9, 2e9))
    assert st.column_range(8) == (4096, 4096)


def test_v1_v2_segments_report_no_stats(tmp_path):
    b = EventBatch.from_events([
        TraceEvent(EventKind.STEP, "step_0", rank=0, issue_ts=0.0,
                   start_ts=0.0, end_ts=1.0, step=0)])
    path = str(tmp_path / "t.fcs")
    store.write_fcs(b, path, version=1)
    store.write_fcs(b, path, version=2)
    stats = list(store.segment_stats(path))
    assert [s.version for s in stats] == [1, 2]
    assert all(not s.has_stats for s in stats)
    # no stats => any predicate must decode the segment
    p = Predicate(step_range=(99, 100))
    assert all(p.may_match(s) for s in stats)


# --------------------------------------------------------------------- #
# predicate semantics
# --------------------------------------------------------------------- #
def test_predicate_unit_semantics():
    with pytest.raises(ValueError, match="unknown severity"):
        Predicate(severity="catastrophic")
    assert Predicate().empty
    assert not Predicate(ranks=[1]).empty

    st = SegmentStats(offset=0, seg_len=100, n_rows=5, version=3,
                      has_stats=True,
                      kind_bits=1 << 0,         # only kind code 0
                      step_min=10, step_max=20, ts_min=5.0, ts_max=9.0,
                      rank_min=4, rank_max=8)
    assert Predicate(step_range=(15, 30)).may_match(st)
    assert not Predicate(step_range=(21, 30)).may_match(st)
    # time windows test span INTERSECTION, inclusive at both ends
    assert Predicate(time_range=(9.0, 12.0)).may_match(st)
    assert not Predicate(time_range=(9.0001, 12.0)).may_match(st)
    assert Predicate(ranks=[8, 99]).may_match(st)
    assert not Predicate(ranks=[0, 3, 9]).may_match(st)
    # empty segment can never match
    empty = SegmentStats(offset=0, seg_len=64, n_rows=0, version=3,
                         has_stats=True)
    assert not Predicate(step_range=(0, 10)).may_match(empty)

    # severity is sugar for a kind set, pruned via the bitmask
    crit = Predicate(severity="critical")
    hang = SegmentStats(
        offset=0, seg_len=100, n_rows=1, version=3, has_stats=True,
        kind_bits=1 << list(EventKind).index(EventKind.HANG_SUSPECT))
    assert crit.may_match(hang) and not crit.may_match(st)

    # exact row filter: span intersection + rank set
    b = EventBatch.from_events([
        TraceEvent(EventKind.KERNEL_COMPUTE, "a", rank=1, issue_ts=0.0,
                   start_ts=0.0, end_ts=2.0, step=0),
        TraceEvent(EventKind.KERNEL_COMPUTE, "b", rank=2, issue_ts=0.0,
                   start_ts=3.0, end_ts=4.0, step=1),
    ])
    got = Predicate(time_range=(1.5, 2.5)).filter(b)
    assert [got.names[i] for i in got.name_id] == ["a"]
    got = Predicate(ranks=[2]).filter(b)
    assert [got.names[i] for i in got.name_id] == ["b"]
    assert Predicate().filter(b) is b


# --------------------------------------------------------------------- #
# pruned reads over mixed-version directories
# --------------------------------------------------------------------- #
def test_pruned_query_byte_equivalent_on_mixed_dir(tmp_path, world):
    prog, _ = world
    d = str(tmp_path / "mixed")
    os.makedirs(d)
    b = ClusterSimulator(N, prog, seed=5).run_batch(6)
    segs = _per_step_segments(b)
    # one job, one file, interleaved v1/v2/v3 segments (the reader
    # dispatches per segment header) plus a rotated all-v3 piece
    base = os.path.join(d, "job-m.fcs")
    for i, sb in enumerate(segs[:4]):
        store.write_fcs(sb, base, version=(1, 2, 3, 3)[i])
    rot = os.path.join(d, "job-m.seg001.fcs")
    for sb in segs[4:]:
        store.write_fcs(sb, rot, version=3)

    ar = TraceArchive(d)
    assert ar.jobs == ["job-m"]
    for pred in (Predicate(step_range=(2, 2)),
                 Predicate(step_range=(4, 5), ranks=[0, 1]),
                 Predicate(severity="warning"),
                 Predicate(time_range=(float(b.start_ts.min()),
                                       float(np.median(b.end_ts))))):
        pruned, scan = ar.query_events("job-m", pred, with_scan=True)
        full, scan_full = ar.query_events("job-m", pred, pushdown=False,
                                          with_scan=True)
        _assert_batches_byte_equal(pruned, full)
        assert scan_full.segments_skipped == 0
        assert scan.bytes_decoded <= scan_full.bytes_decoded

    # a narrow step window must actually skip v3 segments (only the
    # 2 v1/v2 segments + the one matching v3 segment decode)
    _, scan = ar.query_events("job-m", step_range=(3, 3), with_scan=True)
    assert scan.segments_skipped == 3
    assert scan.bytes_skipped > 0
    assert scan.segments == 6


# --------------------------------------------------------------------- #
# corruption: stats block must fail loudly
# --------------------------------------------------------------------- #
def _stats_pos(path):
    with open(path, "rb") as f:
        buf = f.read()
    version, ncols, _, _, names_len, groups_len, extra_len = \
        _parse_header(buf, 0, path)
    assert version == 3
    return buf, _stats_offset(0, ncols, names_len, groups_len, extra_len,
                              _DIRENT2.size)


def test_bitflipped_stats_block_raises(tmp_path):
    b = EventBatch.from_events([
        TraceEvent(EventKind.KERNEL_COMPUTE, "mm", rank=0, issue_ts=0.0,
                   start_ts=0.0, end_ts=1.0, step=3)])
    path = str(tmp_path / "flip.fcs3")
    store.write_fcs(b, path, version=3)
    buf, spos = _stats_pos(path)
    # flip one bit inside step_min (past the CRC field)
    mut = bytearray(buf)
    mut[spos + 8] ^= 0x40
    with open(path, "wb") as f:
        f.write(bytes(mut))
    with pytest.raises(CodecError, match="CRC mismatch"):
        list(store.segment_stats(path))
    with pytest.raises(CodecError, match="CRC mismatch"):
        store.read_fcs(path)


def test_truncated_stats_block_raises(tmp_path):
    b = EventBatch.from_events([
        TraceEvent(EventKind.KERNEL_COMPUTE, "mm", rank=0, issue_ts=0.0,
                   start_ts=0.0, end_ts=1.0, step=3)])
    path = str(tmp_path / "trunc.fcs3")
    store.write_fcs(b, path, version=3)
    buf, spos = _stats_pos(path)
    with open(path, "wb") as f:
        f.write(buf[:spos + 16])        # mid-stats-block
    with pytest.raises(CodecError):
        list(store.segment_stats(path))
    with pytest.raises(CodecError):
        store.read_fcs(path)


def test_fcs3_codec_registered():
    c = store.codec_for_path("x.fcs3")
    assert c.name == "fcs3" and c.version == 3
    assert "fcs3" in store.codecs()


# --------------------------------------------------------------------- #
# rollup cache staleness
# --------------------------------------------------------------------- #
def test_rollup_cache_invalidated_by_segment_append(tmp_path, world):
    prog, _ = world
    d = str(tmp_path / "roll")
    _write_archive(d, prog, steps=4, jobs=("job-a",))
    ar = TraceArchive(d)
    curve = ar.query_metrics("job-a", metric="throughput")
    assert [s for s, _ in curve] == [0, 1, 2, 3]
    builds0 = ar.telemetry.counter("archive.rollup_builds").value
    assert builds0 > 0

    # warm: pure fingerprint hits, zero new builds
    assert ar.query_metrics("job-a", metric="throughput") == curve
    assert ar.telemetry.counter("archive.rollup_builds").value == builds0
    assert ar.telemetry.counter("archive.rollup_hits").value > 0

    # append one more step to ONE file -> exactly one rollup rebuild
    b = ClusterSimulator(N, prog, seed=77).run_batch(5)
    last = _per_step_segments(b)[-1]
    target = sorted(p for p in os.listdir(d) if p.endswith(".fcs3"))[0]
    store.write_fcs(last, os.path.join(d, target), version=3)
    curve2 = ar.query_metrics("job-a", metric="throughput")
    assert [s for s, _ in curve2] == [0, 1, 2, 3, 4]
    assert ar.telemetry.counter("archive.rollup_builds").value == builds0 + 1
    # untouched steps keep their cached records
    assert curve2[:2] == curve[:2]


# --------------------------------------------------------------------- #
# replayer pushdown accounting
# --------------------------------------------------------------------- #
def test_replayer_predicate_accounts_skips(tmp_path, world):
    prog, hist = world
    d = str(tmp_path / "rep")
    _write_archive(d, prog, steps=6, jobs=("job-a",))

    def run(predicate):
        mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=hist)
        mux.add_job("job-a", EngineConfig(backend="dense-train",
                                          num_ranks=N))
        stats = FleetReplayer(mux, predicate=predicate).replay_dir(d)
        mux.finalize()
        return mux, stats

    _, full = run(None)
    mux, pruned = run(Predicate(step_range=(5, 5)))
    assert full.skipped_segments == 0 and full.bytes_skipped == 0
    assert pruned.skipped_segments == 5
    assert pruned.bytes_skipped > 0
    assert 0 < pruned.events < full.events
    assert pruned.bytes_decoded < full.bytes_decoded
    snap = mux.telemetry_snapshot()
    assert snap["counters"]["replay.skipped_segments"] == 5
    assert snap["counters"]["replay.events{job=job-a}"] == pruned.events


# --------------------------------------------------------------------- #
# telemetry round-trip through the archive
# --------------------------------------------------------------------- #
def test_telemetry_covers_pipeline_and_roundtrips(tmp_path, world):
    prog, hist = world
    d = str(tmp_path / "tel")
    _write_archive(d, prog, steps=4, jobs=("job-a",))

    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=hist)
    mux.add_job("job-a", EngineConfig(backend="dense-train", num_ranks=N))
    FleetReplayer(mux).replay_dir(d)
    mux.finalize()

    # a live daemon with its OWN registry attaches; the fleet snapshot
    # merges it in re-tagged with job=...
    daemon = TracingDaemon(DaemonConfig(rank=0, drain_interval=0.01,
                                        hang_timeout=1e9))
    daemon.attach_fleet(mux, "job-live",
                        EngineConfig(backend="dense-train", num_ranks=1))
    daemon.attach()
    daemon.step_begin(0)
    daemon.step_end(tokens=8)
    time.sleep(0.1)
    daemon.stop()

    snap = mux.telemetry_snapshot()
    c = snap["counters"]
    assert c["daemon.events_emitted{job=job-live}"] >= 1    # daemon
    assert c["fleet.late_rows{job=job-a}"] == 0             # multiplexer
    assert c["replay.events{job=job-a}"] > 0                # replayer
    assert snap["gauges"]["fleet.watermark_lag{job=job-a}"] == 0.0

    # export through the archive; the snapshot read back is identical
    ar = TraceArchive(d)
    path = ar.export_telemetry(snap)
    assert os.path.basename(path) == "telemetry-000.json"
    back = ar.telemetry_snapshots()
    assert len(back) == 1
    assert back[0]["counters"] == c
    assert back[0]["gauges"] == snap["gauges"]
    ar.export_telemetry(snap)
    assert len(ar.telemetry_snapshots()) == 2


# --------------------------------------------------------------------- #
# anomalies + fleet weather
# --------------------------------------------------------------------- #
def test_query_anomalies_and_fleet_weather(tmp_path, world):
    prog, hist = world
    d = str(tmp_path / "weather")
    _write_archive(
        d, prog, steps=6,
        injections={"job-b": [Injection(kind="underclock", ranks=(5,),
                                        factor=2.5, start_step=3)]})
    ar = TraceArchive(d, history=hist,
                      engine_config=EngineConfig(backend="dense-train",
                                                 num_ranks=N))
    anoms = ar.query_anomalies(job="job-b")
    assert anoms and all(a.job_id == "job-b" for a in anoms)
    assert ar.query_anomalies(job="job-a", time_range=(-1.0, -0.5)) == []
    # team filter accepts the enum or its string value
    some_team = anoms[0].team
    assert isinstance(some_team, Team)
    by_enum = ar.query_anomalies(team=some_team)
    assert by_enum == ar.query_anomalies(team=some_team.value)
    assert all(a.team is some_team for a in by_enum)
    with pytest.raises(ValueError):
        ar.query_anomalies(team="no-such-team")

    # second query hits the replay cache (directory unchanged)
    hits0 = ar.telemetry.counter("archive.replay_cache_hits").value
    ar.query_anomalies()
    assert ar.telemetry.counter(
        "archive.replay_cache_hits").value == hits0 + 1

    w = ar.fleet_weather()
    assert set(w["jobs"]) == {"job-a", "job-b"}
    assert w["fleet"]["jobs"] == 2
    assert w["jobs"]["job-b"]["anomalies"] > 0
    # underclock from step 3 of 6: second-half throughput drops
    assert w["jobs"]["job-b"]["throughput_trend_pct"] < -5.0
    txt = format_fleet_weather(w)
    assert "job-b" in txt and "fleet: 2 jobs" in txt


# --------------------------------------------------------------------- #
# value-predicate pushdown (per-column bounds)
# --------------------------------------------------------------------- #
def test_value_predicate_unit_semantics():
    with pytest.raises(ValueError, match="unknown predicate column"):
        Predicate(columns={"bogus": (0, 1)})
    assert Predicate(columns={}).empty
    assert Predicate(columns={"flops": (None, None)}).empty
    assert not Predicate(columns={"flops": (1e9, None)}).empty

    cmin, cmax = [0.0] * 11, [0.0] * 11
    cmin[7], cmax[7] = 2e9, 8e9
    st = SegmentStats(offset=0, seg_len=100, n_rows=5, version=3,
                      has_stats=True, kind_bits=1, col_present=1 << 7,
                      col_min=tuple(cmin), col_max=tuple(cmax))
    assert Predicate(columns={"flops": (8e9, None)}).may_match(st)
    assert not Predicate(columns={"flops": (8.1e9, None)}).may_match(st)
    assert Predicate(columns={"flops": (None, 2e9)}).may_match(st)
    assert not Predicate(columns={"flops": (None, 1.9e9)}).may_match(st)
    # column absent from the stats block (all-null) => cannot match:
    # the row filter below would exclude every null row anyway
    assert not Predicate(columns={"nbytes": (0, 1)}).may_match(st)

    # exact row filter is null-aware: rows without the value never match
    b = EventBatch.from_events([
        TraceEvent(EventKind.KERNEL_COMPUTE, "mm", rank=1, issue_ts=0.0,
                   start_ts=0.0, end_ts=1.0, step=0, meta={"flops": 4e9}),
        TraceEvent(EventKind.KERNEL_COMM, "ar", rank=2, issue_ts=0.0,
                   start_ts=0.0, end_ts=1.0, step=0, meta={"bytes": 128}),
    ])
    got = Predicate(columns={"flops": (0.0, None)}).filter(b)
    assert [got.names[i] for i in got.name_id] == ["mm"]
    got = Predicate(columns={"nbytes": (0, 256)}).filter(b)
    assert [got.names[i] for i in got.name_id] == ["ar"]
    assert len(Predicate(columns={"flops": (5e9, None)}).filter(b)) == 0


def test_value_pushdown_byte_equivalent_and_prunes(tmp_path, world):
    prog, _ = world
    d = str(tmp_path / "vals")
    _write_archive(d, prog, steps=6, jobs=("job-a",))
    ar = TraceArchive(d)
    full = ar.query_events("job-a")
    finite = full.flops[~np.isnan(full.flops)]
    assert finite.size > 0
    cut = float(np.median(finite))

    for cols in ({"flops": (cut, None)}, {"flops": (None, cut)},
                 {"nbytes": (1, None)}):
        pruned, scan = ar.query_events("job-a", columns=cols,
                                       with_scan=True)
        oracle, scan_full = ar.query_events("job-a", columns=cols,
                                            pushdown=False, with_scan=True)
        _assert_batches_byte_equal(pruned, oracle)
        assert scan_full.segments_skipped == 0
        assert scan.bytes_decoded <= scan_full.bytes_decoded

    # an impossible bound prunes EVERY v3 segment on stats alone
    none, scan = ar.query_events("job-a", columns={"flops": (1e30, None)},
                                 with_scan=True)
    assert len(none) == 0
    assert scan.segments_skipped == scan.segments > 0
    assert scan.bytes_decoded == 0


# --------------------------------------------------------------------- #
# persistent rollup sidecars
# --------------------------------------------------------------------- #
def test_rollup_disk_cache_warm_across_instances(tmp_path, world):
    prog, _ = world
    d = str(tmp_path / "disk")
    _write_archive(d, prog, steps=4, jobs=("job-a",))
    ar1 = TraceArchive(d)
    curve = ar1.query_metrics("job-a", metric="throughput")
    assert [s for s, _ in curve] == [0, 1, 2, 3]
    sidecars = [p for p in os.listdir(d)
                if p.endswith(store.ROLLUP_SUFFIX)]
    assert sidecars                       # one per rotated piece

    # a COLD instance answers from the sidecars: zero rollup builds
    ar2 = TraceArchive(d)
    assert ar2.query_metrics("job-a", metric="throughput") == curve
    assert ar2.telemetry.counter("archive.rollup_builds").value == 0
    assert ar2.telemetry.counter("archive.rollup_disk_hits").value \
        == len(sidecars)
    # sidecars are data ABOUT traces, not traces
    assert ar2.jobs == ["job-a"]

    # append to one piece -> its fingerprint is stale -> ONE rebuild,
    # the other sidecars still serve from disk
    b = ClusterSimulator(N, prog, seed=78).run_batch(5)
    seg = _per_step_segments(b)[-1]
    target = sorted(p for p in os.listdir(d) if p.endswith(".fcs3"))[0]
    store.write_fcs(seg, os.path.join(d, target), version=3)
    ar3 = TraceArchive(d)
    curve3 = ar3.query_metrics("job-a", metric="throughput")
    assert [s for s, _ in curve3] == [0, 1, 2, 3, 4]
    assert ar3.telemetry.counter("archive.rollup_builds").value == 1
    assert ar3.telemetry.counter("archive.rollup_disk_hits").value \
        == len(sidecars) - 1

    # opt-out: no sidecars written at all
    d2 = str(tmp_path / "nodisk")
    _write_archive(d2, prog, steps=3, jobs=("job-a",))
    ar4 = TraceArchive(d2, persist_rollups=False)
    ar4.query_metrics("job-a", metric="throughput")
    assert not [p for p in os.listdir(d2)
                if p.endswith(store.ROLLUP_SUFFIX)]


def test_rollup_sidecar_corrupt_or_stale_is_ignored(tmp_path, world):
    prog, _ = world
    d = str(tmp_path / "corrupt")
    _write_archive(d, prog, steps=3, jobs=("job-a",))
    ar1 = TraceArchive(d)
    curve = ar1.query_metrics("job-a", metric="throughput")
    side = sorted(p for p in os.listdir(d)
                  if p.endswith(store.ROLLUP_SUFFIX))[0]
    with open(os.path.join(d, side), "w") as f:
        f.write("{ not json")
    ar2 = TraceArchive(d)                  # garbage sidecar -> rebuild
    assert ar2.query_metrics("job-a", metric="throughput") == curve
    assert ar2.telemetry.counter("archive.rollup_builds").value == 1


def test_rollup_sidecars_ignored_by_replay(tmp_path, world):
    prog, hist = world
    d = str(tmp_path / "side")
    _write_archive(d, prog, steps=3, jobs=("job-a",))
    TraceArchive(d).query_metrics("job-a", metric="throughput")
    assert [p for p in os.listdir(d) if p.endswith(store.ROLLUP_SUFFIX)]
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=hist)
    mux.add_job("job-a", EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux).replay_dir(d)
    # only the trace pieces replayed; the .rollup.json sidecars (which
    # the JSONL codec's *.json glob would otherwise claim) are invisible
    assert set(stats.per_job) == {"job-a"}
    assert stats.files == len([p for p in os.listdir(d)
                               if p.endswith(".fcs3")])
    assert stats.skipped_lines == 0 and stats.corrupt_files == 0
