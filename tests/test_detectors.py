"""Detector-registry redesign tests (ISSUE 4).

Covered:
  * registry errors: unknown name (with the known-name list), duplicate
    registration, scope mismatch, replace=True override;
  * DetectorSpec options reach the detector constructor;
  * custom third-party detector end-to-end: registered, resolved by name
    in EngineConfig, fires alongside the built-ins, finalize() flushes
    through ``evaluate_all`` AND through the fleet multiplexer;
  * default-set byte-equivalence vs a frozen port of the pre-registry
    engine if-chain, on traces recorded to FCS and read back;
  * fleet-scope tier: ``CrossJobFailSlowCorrelator`` reclassifies
    co-occurring fail-slows on a shared rack as INFRASTRUCTURE
    (origin="fleet"), leaving unrelated jobs untouched;
  * daemon config plumb-through: ``DaemonConfig.detectors`` picks the
    job's detector set at ``attach_fleet`` time;
  * ``anomalies_json`` coerces numpy scalars/arrays in evidence;
  * ``EventBatch.slice_rows`` view slices equal ``take`` copies, and FCS
    directory replay (the zero-copy path) matches the direct oracle.
"""
import json
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import failslow as fs
from repro.core import regression as rg
from repro.core.anomaly import Anomaly, Team
from repro.core.columnar import KIND_TO_CODE, EventBatch
from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.detectors import (DEFAULT_DETECTORS, Detector, DetectorError,
                                  DetectorSpec, DuplicateDetectorError,
                                  UnknownDetectorError, register_detector,
                                  unregister_detector)
from repro.core.engine import DiagnosticEngine, EngineConfig
from repro.core.events import EventKind
from repro.core.hang import diagnose_hang
from repro.core.history import HistoryStore
from repro.core.metrics import aggregate_all
from repro.core.report import anomalies_json
from repro.core.timeline import (ClusterSimulator, Injection,
                                 program_from_config)
from repro.fleet import (FleetConfig, FleetMultiplexer, FleetReplayer)
from repro import store as trace_store

N = 32

SCENARIOS = {
    "healthy": [],
    "gc": [Injection(kind="gc", duration=0.02, period_ops=5)],
    "underclock": [Injection(kind="underclock", ranks=(5,), factor=2.5,
                             start_step=3)],
    "jitter": [Injection(kind="network_jitter", factor=3.0, start_step=3)],
    "hang": [Injection(kind="hang", ranks=(7,), at_step=2)],
}


@pytest.fixture(scope="module")
def world():
    cfg = get_config("llama-20b-paper")
    prog = program_from_config(cfg, num_chips=N)
    store = HistoryStore()
    eng0 = DiagnosticEngine(
        EngineConfig(backend="dense-train", num_ranks=N), store)
    for seed in range(3):
        eng0.ingest_batch(ClusterSimulator(N, prog, seed=seed).run_batch(4))
    eng0.learn_healthy()
    return prog, store


def _sig(a):
    return (str(a), json.dumps(a.evidence, sort_keys=True, default=str))


def _step_chunks(batch):
    order, uniq, bounds = batch.step_index()
    return [batch.take(order[bounds[i]:bounds[i + 1]])
            for i in range(uniq.size)]


# --------------------------------------------------------------------- #
# registry semantics
# --------------------------------------------------------------------- #
def test_unknown_detector_name_raises():
    with pytest.raises(UnknownDetectorError, match="no_such_detector"):
        DiagnosticEngine(EngineConfig(detectors=["failslow",
                                                 "no_such_detector"]))
    # the error lists what IS registered, so typos are self-diagnosing
    with pytest.raises(UnknownDetectorError, match="failslow"):
        DiagnosticEngine(EngineConfig(detectors=["no_such_detector"]))


def test_duplicate_registration_raises():
    @register_detector
    class _Dup(Detector):
        name = "dup_test_detector"
        kind = "regression"
    try:
        with pytest.raises(DuplicateDetectorError, match="dup_test_detector"):
            register_detector(type("_Dup2", (Detector,),
                                   {"name": "dup_test_detector"}))

        # replace=True is the sanctioned override
        @register_detector(replace=True)
        class _Dup3(Detector):
            name = "dup_test_detector"
            kind = "regression"
        eng = DiagnosticEngine(EngineConfig(detectors=["dup_test_detector"]))
        assert type(eng.detectors[0]).__name__ == "_Dup3"
    finally:
        unregister_detector("dup_test_detector")


def test_scope_mismatch_rejected():
    # a fleet-scope name cannot be resolved into the per-job engine set
    with pytest.raises(DetectorError):
        DiagnosticEngine(EngineConfig(detectors=["cross_job_failslow"]))


def test_detector_spec_options_reach_constructor(world):
    prog, store = world
    eng = DiagnosticEngine(EngineConfig(
        backend="dense-train", num_ranks=N,
        detectors=[DetectorSpec("failslow", {"window": 4, "drop": 0.5})]),
        store)
    d = eng.detectors[0]
    assert d._monitor.window == 4 and d._monitor.drop_threshold == 0.5


# --------------------------------------------------------------------- #
# custom third-party detector, end-to-end
# --------------------------------------------------------------------- #
def _make_custom():
    @register_detector
    class ThroughputFloorDetector(Detector):
        """Fires when throughput dips below an absolute floor; emits one
        summary finding from finalize()."""
        name = "throughput_floor"
        kind = "regression"

        def __init__(self, floor: float = 0.0):
            self.floor = floor
            self.low_steps = []

        def observe_step(self, m, step):
            if m.throughput < self.floor:
                self.low_steps.append(step)
                return [Anomaly(
                    kind="regression", metric="throughput_floor",
                    team=Team.CROSS_TEAM,
                    root_cause=f"throughput below floor {self.floor:g}",
                    step=step,
                    evidence={"throughput": np.float64(m.throughput)})]
            return []

        def finalize(self):
            if not self.low_steps:
                return []
            return [Anomaly(
                kind="regression", metric="throughput_floor_summary",
                team=Team.CROSS_TEAM,
                root_cause=f"{len(self.low_steps)} step(s) below floor",
                step=self.low_steps[-1],
                evidence={"steps": list(self.low_steps)})]
    return ThroughputFloorDetector


def test_custom_detector_end_to_end(world):
    prog, store = world
    _make_custom()
    try:
        spec = [*DEFAULT_DETECTORS,
                DetectorSpec("throughput_floor", {"floor": 1e18})]
        batch = ClusterSimulator(N, prog, seed=7,
                                 injections=SCENARIOS["gc"]).run_batch(5)

        # default built-ins still fire identically next to the plugin
        base = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        base.ingest_batch(batch)
        base_sigs = [_sig(a) for a in base.evaluate_all()]

        eng = DiagnosticEngine(EngineConfig(
            backend="dense-train", num_ranks=N, detectors=spec), store)
        eng.ingest_batch(batch)
        out = eng.evaluate_all()
        custom = [a for a in out if a.metric.startswith("throughput_floor")]
        rest = [_sig(a) for a in out
                if not a.metric.startswith("throughput_floor")]
        assert rest == base_sigs
        # fired every step (absurd floor), plus the finalize summary
        assert [a.step for a in custom
                if a.metric == "throughput_floor"] == [0, 1, 2, 3, 4]
        assert custom[-1].metric == "throughput_floor_summary"

        # same plugin streams through the fleet: finalize() lands on the
        # merged stream with team routing
        mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
        mux.add_job("j", EngineConfig(backend="dense-train", num_ranks=N,
                                      detectors=spec))
        for c in _step_chunks(batch):
            mux.ingest("j", c)
        fleet_sigs = [_sig(fa.anomaly) for fa in mux.poll() + mux.finalize()]
        assert fleet_sigs == [_sig(a) for a in out]
    finally:
        unregister_detector("throughput_floor")


# --------------------------------------------------------------------- #
# default set == frozen pre-registry engine, on recorded traces
# --------------------------------------------------------------------- #
def _legacy_evaluate_all(cfg: EngineConfig, history: HistoryStore,
                         batch: EventBatch) -> list:
    """Frozen port of the PR-3 DiagnosticEngine if-chain (the pre-registry
    behavior oracle).  Do not refactor against src/ — drift from this
    verbatim copy is exactly what the test exists to catch."""
    tp = fs.ThroughputMonitor(cfg.failslow_window, cfg.failslow_drop)
    pending: dict[str, int] = {}
    baseline = None
    prof = history.get(cfg.backend, cfg.num_ranks)
    out = []

    def also_low_at_start(finding, base_m):
        name = finding.evidence.get("kernel", "")
        base = base_m.bandwidth.get(name)
        exp = prof.expected_bandwidth.get(name)
        if base is None or not exp:
            return True
        return base < rg.BW_REGRESSION_FRAC * exp

    ms_all = aggregate_all(batch)
    for step in sorted(ms_all):
        m = ms_all[step]
        if baseline is None:
            baseline = m
        drop = tp.observe(m.throughput)
        if drop is not None:
            f = fs.attribute_failslow(m, baseline, step, drop)
            out.append(Anomaly(
                kind="fail_slow", metric="throughput", team=Team.OPERATIONS,
                root_cause={"gpu_underclock":
                            f"GPU underclocking on ranks {f.ranks}",
                            "network":
                            "network degradation (jitter/congestion); "
                            "binary-search probe plan attached",
                            "unknown": "sudden slowdown, cause unresolved"
                            }[f.cause],
                step=step, severity=1.0 + drop, ranks=f.ranks,
                evidence={"drop_frac": drop, **f.evidence,
                          "probe_plan": f.probe_plan}))
        base_bw = baseline.bandwidth
        slow_groups = [(n, bw / base_bw[n]) for n, bw in m.bandwidth.items()
                       if n in base_bw and base_bw[n] > 0
                       and bw < 0.75 * base_bw[n]]
        if slow_groups and m is not baseline:
            out.append(Anomaly(
                kind="fail_slow", metric="bandwidth", team=Team.OPERATIONS,
                root_cause="network degradation on "
                           f"{len(slow_groups)} collective group(s) "
                           "(jitter/CRC/congestion); probe plan attached",
                step=step, severity=1.0 / min(f for _, f in slow_groups),
                evidence={"slow_groups": slow_groups[:6],
                          "probe_plan": fs.binary_search_plan(m.num_ranks)}))
        if prof is not None:
            findings = []
            il = rg.check_issue_latency(m, prof)
            if il:
                findings.append(il)
            findings.extend(rg.check_voids(m, prof))
            flops_f = rg.check_flops(m, prof)
            rg.annotate_layout(flops_f, cfg.kernel_shapes)
            findings.extend(flops_f)
            bw_f = [f for f in rg.check_bandwidth(m, prof)
                    if also_low_at_start(f, baseline)]
            findings.extend(bw_f)
            if any(f.metric == "v_inter" for f in findings):
                findings = [f for f in findings
                            if not (f.metric == "issue_latency"
                                    and "dataloader" in f.root_cause.lower())]
            for f in findings:
                pending[f.metric] = pending.get(f.metric, 0) + 1
                if pending[f.metric] >= cfg.regression_consecutive:
                    out.append(Anomaly(
                        kind="regression", metric=f.metric,
                        team=Team(f.suggested_team),
                        root_cause=f.root_cause, step=step,
                        severity=f.severity, evidence=f.evidence))
            fired = {f.metric for f in findings}
            for key in list(pending):
                if key not in fired:
                    pending[key] = 0

    # hang check (majority of distinct ranks with HANG_SUSPECT rows)
    c_hang = KIND_TO_CODE[EventKind.HANG_SUSPECT]
    suspects = {}
    for row in np.nonzero(batch.kind == c_hang)[0].tolist():
        stack = (batch.extra.get(row) or {}).get("stack", [])
        suspects[int(batch.rank[row])] = stack
    if len(suspects) >= max(batch.num_distinct_ranks() // 2, 1):
        d = diagnose_hang(suspects, None)
        out.append(Anomaly(
            kind="hang",
            metric="intra_kernel_inspecting" if d.used_inspector
            else "call_stack_analysis",
            team=Team.OPERATIONS,
            root_cause=d.detail, ranks=d.faulty_ranks,
            evidence={"hang_kind": d.kind, "link": d.link}))
    return out


def test_default_set_matches_legacy_engine_on_recorded_traces(world,
                                                              tmp_path):
    prog, store = world
    cfg = EngineConfig(backend="dense-train", num_ranks=N)
    for name, inj in SCENARIOS.items():
        path = str(tmp_path / f"{name}.fcs")
        trace_store.write_trace(
            ClusterSimulator(N, prog, seed=7, injections=inj).run_batch(6),
            path)
        recorded = trace_store.read_trace(path)
        legacy = [_sig(a) for a in _legacy_evaluate_all(cfg, store, recorded)]
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(recorded)
        assert [_sig(a) for a in eng.evaluate_all()] == legacy, name
    assert any(len(_legacy_evaluate_all(
        cfg, store,
        ClusterSimulator(N, prog, seed=7,
                         injections=SCENARIOS[k]).run_batch(6))) > 0
        for k in ("gc", "underclock", "jitter", "hang"))


# --------------------------------------------------------------------- #
# fleet-scope tier: cross-job fail-slow correlation
# --------------------------------------------------------------------- #
def test_cross_job_failslow_reclassified_infrastructure(world):
    """Two jobs on the same rack hit by the same network degradation are
    reclassified INFRASTRUCTURE by the correlator; the healthy job on
    another rack stays clean."""
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(
        watermark_delay=1, fleet_detectors=["cross_job_failslow"]),
        history=store)
    jobs = {
        "jobA": SCENARIOS["jitter"],
        "jobB": SCENARIOS["underclock"],
        "jobC": [],
    }
    mux.set_topology("jobA", rack="rack7", switch="sw-12")
    mux.set_topology("jobB", rack="rack7", switch="sw-99")
    mux.set_topology("jobC", rack="rack2", switch="sw-12")
    pending = {}
    for job_id, inj in jobs.items():
        mux.add_job(job_id, EngineConfig(backend="dense-train", num_ranks=N))
        b = ClusterSimulator(N, prog, seed=7, injections=inj).run_batch(6)
        pending[job_id] = _step_chunks(b)
    while any(pending.values()):
        for job_id, chunks in pending.items():
            if chunks:
                mux.ingest(job_id, chunks.pop(0))
    out = mux.poll() + mux.finalize()
    fleet = [fa for fa in out if fa.origin == "fleet"]
    assert fleet, "correlator emitted nothing"
    assert {fa.job_id for fa in fleet} == {"jobA", "jobB"}
    for fa in fleet:
        a = fa.anomaly
        assert a.team is Team.INFRASTRUCTURE
        assert a.metric == "cross_job_correlation"
        assert "rack7" in a.root_cause
        assert a.evidence["rack"] == "rack7"
        assert a.evidence["jobs"] == ["jobA", "jobB"]
        assert fa.route == "oncall-infrastructure"
    # one reclassification per (rack, job): repeated fail-slow steps do
    # not spam the stream
    assert len(fleet) == 2
    # per-job anomalies are unchanged next to the fleet tier
    assert all(fa.origin == "job" for fa in out if fa not in fleet)
    assert not any(fa.job_id == "jobC" for fa in out)


def test_correlator_ignores_single_job_and_unmapped_jobs(world):
    prog, store = world
    mux = FleetMultiplexer(FleetConfig(
        watermark_delay=1, fleet_detectors=["cross_job_failslow"]),
        history=store)
    mux.set_topology("solo", rack="rack1")
    # "nomap" never gets topology: fail-slows there cannot correlate
    for job_id in ("solo", "nomap"):
        mux.add_job(job_id, EngineConfig(backend="dense-train", num_ranks=N))
        b = ClusterSimulator(N, prog, seed=7,
                             injections=SCENARIOS["jitter"]).run_batch(6)
        for c in _step_chunks(b):
            mux.ingest(job_id, c)
    out = mux.poll() + mux.finalize()
    assert [fa for fa in out if fa.origin == "fleet"] == []
    assert any(fa.anomaly.kind == "fail_slow" for fa in out)


def test_daemon_config_detectors_plumb_through():
    mux = FleetMultiplexer(FleetConfig(watermark_delay=0))
    d = TracingDaemon(DaemonConfig(rank=0, hang_timeout=1e9,
                                   detectors=["failslow", "hang"],
                                   num_ranks=8))
    d.attach_fleet(mux, "plumbed")
    eng = mux.job("plumbed").engine
    assert [det.name for det in eng.detectors] == ["failslow", "hang"]
    assert eng.cfg.num_ranks == 8


# --------------------------------------------------------------------- #
# satellites: anomalies_json numpy coercion; zero-copy slices
# --------------------------------------------------------------------- #
def test_anomalies_json_coerces_numpy_evidence():
    a = Anomaly(
        kind="fail_slow", metric="throughput", team=Team.OPERATIONS,
        root_cause="x", step=np.int64(4), severity=np.float64(1.5),
        ranks=[np.int64(3), np.int64(5)],
        evidence={"drop_frac": np.float32(0.2),
                  "outlier_ranks": np.array([3, 5]),
                  "per_kernel": {"mm": np.float64(0.5)},
                  "names": {"a", "b"}})
    out = json.loads(anomalies_json([a]))
    assert out[0]["step"] == 4 and out[0]["ranks"] == [3, 5]
    ev = out[0]["evidence"]
    assert ev["outlier_ranks"] == [3, 5]
    assert ev["per_kernel"]["mm"] == 0.5
    assert abs(ev["drop_frac"] - 0.2) < 1e-6
    assert sorted(ev["names"]) == ["a", "b"]


def test_slice_rows_views_equal_take(world):
    prog, _ = world
    batch = ClusterSimulator(
        N, prog, seed=3,
        injections=SCENARIOS["hang"]).run_batch(4)    # hang => extra dicts
    assert batch.is_step_sorted()
    order, uniq, bounds = batch.step_index()
    for j in range(uniq.size):
        lo, hi = int(bounds[j]), int(bounds[j + 1])
        view = batch.slice_rows(lo, hi)
        copy = batch.take(order[lo:hi])
        # views share memory with the parent columns, takes do not
        assert np.shares_memory(view.end_ts, batch.end_ts)
        assert view.to_events() == copy.to_events()


def test_fcs_replay_uses_views_and_matches_direct_oracle(world, tmp_path):
    prog, store = world
    logdir = tmp_path / "logs"
    os.makedirs(logdir)
    jobs = {"jobA-gc": SCENARIOS["gc"], "jobB-jitter": SCENARIOS["jitter"]}
    oracle = {}
    for job_id, inj in jobs.items():
        b = ClusterSimulator(N, prog, seed=7, injections=inj).run_batch(5)
        trace_store.write_trace(b, str(logdir / f"{job_id}.fcs"))
        eng = DiagnosticEngine(
            EngineConfig(backend="dense-train", num_ranks=N), store)
        eng.ingest_batch(trace_store.read_trace(str(logdir / f"{job_id}.fcs")))
        oracle[job_id] = [_sig(a) for a in eng.evaluate_all()]
    mux = FleetMultiplexer(FleetConfig(watermark_delay=1), history=store)
    for job_id in jobs:
        mux.add_job(job_id, EngineConfig(backend="dense-train", num_ranks=N))
    stats = FleetReplayer(mux).replay_dir(str(logdir))
    got = {j: [] for j in jobs}
    for fa in mux.poll() + mux.finalize():
        got[fa.job_id].append(_sig(fa.anomaly))
    assert stats.files == 2 and stats.corrupt_files == 0
    for job_id in jobs:
        assert got[job_id] == oracle[job_id], job_id
