"""Parameter spec rules, divisibility sanitizer, ZeRO spec behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.models import build_model
from repro.parallel.sharding import param_specs, sanitize_spec, zero_spec


class FakeMesh:
    axis_names = ("pod", "data", "model")
    shape = {"pod": 2, "data": 16, "model": 16}


MESH = FakeMesh()


def test_param_specs_rules():
    cfg = get_reduced("dbrx-132b")
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    specs = param_specs(p)
    assert specs["embed"]["embedding"] == P("model", None)
    assert specs["head"]["w"] == P(None, "model")
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model", None)
    assert specs["layers"]["moe"]["wi_gate"] == P(None, "model", None, None)
    assert specs["layers"]["ln1"]["scale"] == P(None, None)


def test_sanitize_relocates_model_axis():
    # kv_heads=2 cannot shard 16 ways -> relocate to head_dim=64
    s = sanitize_spec(P(None, "model", None), (24, 2, 64), MESH)
    assert s == P(None, None, "model")
    # nothing divisible -> replicate
    s = sanitize_spec(P("model",), (6,), MESH)
    assert s == P(None,)
    # already fine -> unchanged
    s = sanitize_spec(P(None, "model"), (10, 32), MESH)
    assert s == P(None, "model")
    # never relocate onto the leading (scan) dim
    s = sanitize_spec(P(None, "model"), (32, 6), MESH)
    assert s == P(None, None)


def test_zero_spec_adds_data_once():
    s = zero_spec(P(None, "model"), (64, 32), MESH, axes=("data",))
    assert s == P("data", "model")
    # idempotent: never duplicates the data axis
    s2 = zero_spec(s, (64, 32), MESH, axes=("data",))
    assert s2 == s
    # skips non-divisible dims
    s3 = zero_spec(P(None, None), (6, 32), MESH, axes=("data",))
    assert s3 == P(None, "data")


def test_zero_spec_multi_axis():
    s = zero_spec(P(None, None), (64, 7), MESH, axes=("pod", "data"))
    assert s == P(("pod", "data"), None)
