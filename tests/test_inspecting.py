"""O(1) intra-kernel inspecting: exactness + cost-model properties."""
import numpy as np
import pytest

from repro.core.inspecting import (diagnose_ring, inspect_cost_model,
                                   probe_search_cost)
from repro.core.timeline import ClusterSimulator, Injection, SimOp


def _sim_progress(n, fault, s0=7, fifo=2, total=None):
    """Use the simulator's hang model to produce ring progress."""
    prog = [SimOp("allreduce[0]", "comm", 1e-3, bytes=1024)]
    sim = ClusterSimulator(n, prog, injections=[
        Injection(kind="hang", ranks=(fault,), at_step=0,
                  meta={"frozen_at": s0, "fifo_depth": fifo})],
        ring_total_steps=total or 2 * (n - 1))
    sim.run(1)
    return sim.hang.ring_progress


@pytest.mark.parametrize("n", [4, 16, 64, 256, 1024])
def test_ring_diagnosis_localizes_fault(n):
    for fault in {0, 1, n // 2, n - 1}:
        progress = _sim_progress(n, fault)
        d = diagnose_ring(progress)
        assert fault in d.machines, (n, fault, d)
        assert d.link == (fault, (fault + 1) % n)


def test_inspect_cost_constant_in_cluster_size():
    c1 = inspect_cost_model(16)
    c2 = inspect_cost_model(4096)
    assert c1 == c2  # O(1)
    # paper Fig 10 band: 29.4 - 309.2 s
    for proto in ("SIMPLE", "LL128", "LL"):
        for inter in (True, False):
            c = inspect_cost_model(1024, proto, inter)
            assert 20.0 <= c <= 320.0


def test_probe_search_grows_and_exceeds_30min_at_scale():
    small = probe_search_cost(64)
    big = probe_search_cost(4096)
    assert big > small
    assert probe_search_cost(2048) >= 1800.0  # paper: >= 30 min
    assert inspect_cost_model(2048) <= 310.0  # paper: <= ~5 min


def test_multi_min_progress_low_confidence():
    p = np.array([5, 5, 9, 9])
    d = diagnose_ring(p)
    assert d.confidence == "review"
