"""Tracing daemon + interceptor unit tests."""
import gc
import json
import os
import time

import pytest

from repro.core.daemon import DaemonConfig, TracingDaemon
from repro.core.events import (EventKind, EventRingBuffer, TraceEvent,
                               load_jsonl)
from repro.core.interceptor import parse_api_spec


def test_parse_api_spec():
    assert parse_api_spec("gc@collect, json@dumps") == [
        ("gc", "collect"), ("json", "dumps")]
    with pytest.raises(ValueError):
        parse_api_spec("nodelimiter")


def test_event_codec_roundtrip():
    ev = TraceEvent(EventKind.KERNEL_COMM, "allreduce", 3, 1.0, 1.5, 2.0,
                    step=7, meta={"bytes": 1024, "group": "dp"})
    ev2 = TraceEvent.from_json(ev.to_json())
    assert ev2.name == "allreduce" and ev2.rank == 3
    assert ev2.issue_latency == pytest.approx(0.5)
    assert ev2.meta["bytes"] == 1024


def test_ring_buffer_overflow():
    buf = EventRingBuffer(capacity=4)
    for i in range(7):
        buf.append(TraceEvent(EventKind.STEP, f"e{i}", 0, i, i, i + 1))
    assert buf.dropped == 3
    names = [e.name for e in buf.drain()]
    assert names == ["e3", "e4", "e5", "e6"]
    assert len(buf) == 0


def test_daemon_traces_env_api_gc_and_kernels(tmp_path):
    os.environ["FLARE_TRACED_PYTHON_API"] = "json@dumps"
    try:
        log = str(tmp_path / "t.jsonl")
        d = TracingDaemon(DaemonConfig(rank=1, log_path=log,
                                       drain_interval=0.01,
                                       hang_timeout=1e9))
        d.attach()
        got = []
        d.add_sink(lambda evs: got.extend(evs))
        d.step_begin(0)
        json.dumps([1, 2, 3])
        gc.collect()

        @d.register_kernel("k1", EventKind.KERNEL_COMPUTE,
                           lambda x: {"flops": 10.0})
        def op(x):
            return x * 2

        op(21)
        d.step_end(tokens=64)
        time.sleep(0.25)
        d.detach()
        kinds = {e.kind for e in got}
        assert EventKind.GC in kinds
        assert EventKind.STEP in kinds
        assert any(e.name == "json@dumps" for e in got)
        k = [e for e in got if e.name == "k1"]
        assert k and k[0].meta["flops"] == 10.0
        # kernel nests under the step span (stack reconstruction)
        assert k[0].meta.get("parent") == "step_0"
        # logged bytes and reload
        assert d.bytes_logged > 0
        reloaded = load_jsonl(log)
        assert len(reloaded) == len(got)
        # observer-effect guard: daemon's own json.dumps not traced
        dumps_count = sum(1 for e in got if e.name == "json@dumps")
        assert dumps_count == 1
    finally:
        del os.environ["FLARE_TRACED_PYTHON_API"]


def test_daemon_hang_heartbeat():
    d = TracingDaemon(DaemonConfig(rank=0, hang_timeout=0.05,
                                   drain_interval=0.01))
    d.attach()
    reports = []
    d.on_hang(reports.append)
    d.step_begin(0)
    d.set_stack(["train_step", "allreduce"])
    time.sleep(0.3)
    d.detach()
    assert reports and reports[0]["stack"] == ["train_step", "allreduce"]
